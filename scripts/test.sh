#!/usr/bin/env sh
# Tier-1 suite in one line: PYTHONPATH=src + pytest from the repo root.
# Extra args pass through, e.g. scripts/test.sh -k gram_dispatch
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"

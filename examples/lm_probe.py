"""The paper's technique integrated into the LM pipeline: fit a linear probe
(ridge readout) on frozen LM hidden states with CA-BDCD.

This is exactly the paper's extension direction ("kernel ridge regression /
features" -- section 6): the design matrix is the LM's last-hidden-state
features X in R^{d_model x n_tokens}, the targets are scalar labels derived
from the next token, and the CA solver fits the probe while synchronizing
only every s iterations -- the same fused Gram-packet schedule as the
standalone solver.

Run:  PYTHONPATH=src python examples/lm_probe.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import dataclasses  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.core import ca_bdcd, bdcd, ridge_exact, sample_blocks  # noqa: E402
from repro.data import synthetic_lm_batch  # noqa: E402
from repro.models import api, init_params  # noqa: E402
from repro.models import layers as L  # noqa: E402


def extract_features(cfg, params, batch):
    """Last-hidden-state features before the LM head: (d_model, tokens)."""
    x = L.embed(params, jnp.asarray(batch["tokens"])).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1])[None, :]
    from repro.models.api import _decoder_stack
    h, _ = _decoder_stack(params, cfg, x, positions)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    d = h.shape[-1]
    return h.reshape(-1, d).T.astype(jnp.float64)   # (d_model, n_tokens)


def main(seed: int = 0):
    cfg = dataclasses.replace(get_reduced("llama3_2_3b"),
                              dtype=jnp.float32, param_dtype=jnp.float32)
    # Fixed default seed => reproducible probe accuracy line in CI logs
    # (seed=0 reproduces the historical key(0)/seed=3/key(4) stream).
    params = init_params(api.param_specs(cfg), jax.random.key(seed))
    batch = synthetic_lm_batch(cfg.vocab, seq_len=128, batch=8, seed=seed + 3)

    X = extract_features(cfg, params, batch)
    # probe target: is the NEXT token in the top half of the vocab?
    y = (2.0 * (np.asarray(batch["labels"]).reshape(-1) > cfg.vocab // 2)
         - 1.0).astype(np.float64)
    y = jnp.asarray(y)
    d, n = X.shape
    lam = 1e-4 * float(jnp.linalg.norm(X) ** 2 / n)
    print(f"probe design matrix: {d} features x {n} tokens, lambda={lam:.2e}")

    w_opt = ridge_exact(X, y, lam)
    iters, b, s = 200, 32, 10
    idx = sample_blocks(jax.random.key(seed + 4), n, b, iters)
    res_cl = bdcd(X, y, lam, b, iters, None, idx=idx, w_ref=w_opt)
    res_ca = ca_bdcd(X, y, lam, b, s, iters, None, idx=idx, w_ref=w_opt)

    dev = float(np.max(np.abs(res_ca.w - res_cl.w)))
    err = float(res_ca.history["sol_err"][-1])
    acc = float(np.mean(np.sign(np.asarray(X.T @ res_ca.w)) == np.asarray(y)))
    print(f"CA-BDCD == BDCD on LM features: max |w diff| = {dev:.2e}")
    print(f"probe solution error vs exact ridge: {err:.2e}")
    print(f"probe train accuracy: {acc:.3f}")
    print(f"synchronizations: {iters} (classical) vs {iters//s} (CA, s={s})")
    assert dev < 1e-8


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for params/batch/index stream (fixed "
                         "default => reproducible output)")
    main(seed=ap.parse_args().seed)

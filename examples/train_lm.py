"""End-to-end LM training driver (deliverable b).

    PYTHONPATH=src python examples/train_lm.py                  # cpu-small
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # ~100M params

Trains a reduced-geometry model from the assigned-arch families on the
synthetic affine-next-token stream (loss demonstrably falls), with
checkpointing + exact resume.  Thin wrapper over repro.launch.train so the
example and the production launcher share every code path.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    if "--preset" not in " ".join(sys.argv):
        sys.argv += ["--preset", "cpu-small"]
    if "--ckpt-dir" not in " ".join(sys.argv):
        sys.argv += ["--ckpt-dir", "/tmp/repro_train_lm_ckpt"]
    main()

"""Batched serving example: continuous batching over the slot engine
(prefill buckets + single jit'd decode for all slots).

    PYTHONPATH=src python examples/serve_lm.py --arch llama3_2_3b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()

"""Sparse recovery with CA proximal BCD (elastic net) -- the third formulation.

Solves   min_w 1/(2n) ||X^T w - y||^2 + lam/2 ||w||^2 + lam1 ||w||_1
through the same s-step engine as the ridge solvers (arXiv:1712.06047):
ONE sb x sb Gram packet per outer iteration, soft-threshold inside the inner
recurrence.  Shows
  1. identical trajectories for s=1 and s>1 (the CA claim survives the
     nonsmooth term), and
  2. support recovery: lam1 drives most coordinates to EXACT zeros while the
     communication count drops by s.

Run:  PYTHONPATH=src python examples/lasso.py [--impl ref|pallas|pallas_interpret]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import elastic_net_objective, get_solver, sample_blocks  # noqa: E402


def main(impl: str | None = None, seed: int = 0):
    solve = get_solver("proximal", "local")
    d, n, k = 256, 1024, 16                    # k-sparse ground truth
    # Fixed default seed: the 16/16 support-recovery line below is
    # reproducible run-to-run in CI logs (seed=0 is the historical stream).
    key = jax.random.key(seed)
    X = jax.random.normal(key, (d, n), jnp.float64)
    w_true = jnp.zeros((d,)).at[jnp.arange(k) * (d // k)].set(1.0)
    y = X.T @ w_true + 0.02 * jax.random.normal(jax.random.key(seed + 1), (n,))
    lam = 1e-4
    lam1 = 0.1 * float(jnp.max(jnp.abs(X @ y)) / n)
    print(f"problem: X {X.shape}, ||w_true||_0 = {k}, "
          f"lam={lam:.1e}, lam1={lam1:.3e}")

    iters, b, s = 600, 8, 20
    idx = sample_blocks(jax.random.key(seed + 2), d, b, iters)

    res_cl = solve(X, y, lam, b, 1, iters, None, idx=idx, lam1=lam1, impl=impl)
    res_ca = solve(X, y, lam, b, s, iters, None, idx=idx, lam1=lam1, impl=impl)

    dev = np.max(np.abs(np.asarray(res_ca.history["objective"]) -
                        np.asarray(res_cl.history["objective"])))
    nnz = int(res_ca.history["nnz"][-1])
    support = np.flatnonzero(np.asarray(res_ca.w))
    true_support = np.flatnonzero(np.asarray(w_true))
    print(f"\nPBCD     : {iters} iterations -> {iters} synchronizations")
    print(f"CA-PBCD  : {iters} iterations -> {iters//s} synchronizations "
          f"(s={s}, soft-threshold inside the inner recurrence)")
    print(f"max |objective difference| over the trajectory: {dev:.2e}")
    print(f"final objective: "
          f"{float(elastic_net_objective(X, res_ca.w, y, lam, lam1)):.4e}")
    print(f"sparsity: {nnz}/{d} nonzeros (true support {k}); "
          f"recovered {len(np.intersect1d(support, true_support))}/{k} "
          f"true coordinates")
    assert dev < 1e-8, "CA-PBCD must match classical proximal BCD exactly"
    assert nnz < d // 2, "lam1 at this level must produce a sparse iterate"
    print("\nsame iterates, exact zeros, 1/s the synchronizations.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend: ref | pallas | pallas_interpret")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for data/noise/index stream (fixed "
                         "default => reproducible 16/16 recovery line)")
    args = ap.parse_args()
    main(args.impl, seed=args.seed)

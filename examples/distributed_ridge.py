"""Distributed CA-BCD/CA-BDCD across 8 (simulated) devices via shard_map.

Spawns itself with XLA_FLAGS=--xla_force_host_platform_device_count=8 so the
parent environment keeps its device world untouched, then:
  * runs CA-BCD with X column-sharded (1D-block-column, Theorem 6) and
    CA-BDCD with X row-sharded (1D-block-row, Theorem 7),
  * verifies both against the single-device reference,
  * counts collectives in the compiled HLO: classical = H, CA = H/s.

Run:  PYTHONPATH=src python examples/distributed_ridge.py
"""
import os
import subprocess
import sys

PAYLOAD = "_IS_DISTRIBUTED_CHILD"


def child():
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import (count_in_compiled, get_solver, make_solver_mesh,
                            sample_blocks)
    from repro.core.distributed import lower_solver
    from repro.data import SyntheticSpec, make_regression

    impl = os.environ.get("REPRO_GRAM_IMPL") or None
    # Fixed default seed (threaded from the parent's --seed): reproducible
    # error lines in CI logs; seed=0 is the historical stream.
    seed = int(os.environ.get("REPRO_SEED", "0"))
    print(f"devices: {len(jax.devices())}")
    mesh = make_solver_mesh(8)
    X, y, _ = make_regression(jax.random.key(seed),
                              SyntheticSpec("dist", d=128, n=4096, cond=1e6))
    lam, b, s, iters = 1e-3, 8, 8, 64

    # Both formulations x both backends come from the same solver registry.
    primal, primal_sh = get_solver("primal"), get_solver("primal", "sharded")
    dual, dual_sh = get_solver("dual"), get_solver("dual", "sharded")

    idx = sample_blocks(jax.random.key(seed + 1), 128, b, iters)
    w_dist, _ = primal_sh(mesh, X, y, lam, b, s, iters, None, idx=idx,
                          impl=impl)
    w_ref = primal(X, y, lam, b, s, iters, None, idx=idx, impl=impl).w
    print(f"CA-BCD  1D-col: |w_dist - w_single| = "
          f"{float(np.max(np.abs(w_dist - w_ref))):.2e}")

    idx2 = sample_blocks(jax.random.key(seed + 2), 4096, 16, iters)
    w2, _ = dual_sh(mesh, X, y, lam, 16, s, iters, None, idx=idx2, impl=impl)
    w2_ref = dual(X, y, lam, 16, s, iters, None, idx=idx2, impl=impl).w
    print(f"CA-BDCD 1D-row: |w_dist - w_single| = "
          f"{float(np.max(np.abs(w2 - w2_ref))):.2e}")

    cl = lower_solver("primal", mesh, 128, 4096, lam, b, 1, iters,
                      fuse_packet=True, unroll=iters, impl=impl)
    ca = lower_solver("primal", mesh, 128, 4096, lam, b, s, iters,
                      fuse_packet=True, unroll=iters // s, impl=impl)
    n_cl, n_ca = count_in_compiled(cl).count, count_in_compiled(ca).count
    print(f"collectives per {iters} iterations: classical={n_cl}, "
          f"CA(s={s})={n_ca}  -> latency / {n_cl // n_ca}")


def main():
    if os.environ.get(PAYLOAD):
        child()
        return
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend: ref | pallas | pallas_interpret")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for data + index streams (fixed default "
                         "=> reproducible output)")
    args = ap.parse_args()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[PAYLOAD] = "1"
    env["REPRO_SEED"] = str(args.seed)
    if args.impl:
        env["REPRO_GRAM_IMPL"] = args.impl
    env.setdefault("PYTHONPATH", "src")
    sys.exit(subprocess.run([sys.executable, os.path.abspath(__file__)],
                            env=env).returncode)


if __name__ == "__main__":
    main()

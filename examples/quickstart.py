"""Quickstart: the paper in 60 seconds.

Solves a ridge problem with classical BCD and CA-BCD(s), showing
  1. identical convergence trajectories (the exact-arithmetic claim), and
  2. s-fold fewer synchronization points (the latency claim).

Run:  PYTHONPATH=src python examples/quickstart.py [--impl ref|pallas|pallas_interpret]
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import get_solver, ridge_exact, sample_blocks  # noqa: E402
from repro.data import SyntheticSpec, make_regression  # noqa: E402


def main(impl: str | None = None, seed: int = 0):
    # One engine, one registry: classical BCD is the primal solver at s=1.
    solve = get_solver("primal", "local")
    # A news20-shaped problem: more features than data points, ill-conditioned.
    # The fixed default seed makes this output (incl. the printed errors)
    # reproducible run-to-run in CI logs; seed=0 is the historical stream.
    X, y, _ = make_regression(jax.random.key(seed),
                              SyntheticSpec("demo", d=512, n=2048, cond=1e6))
    lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
    w_opt = ridge_exact(X, y, lam)
    print(f"problem: X {X.shape}, lambda={lam:.3e}")

    iters, b, s = 1000, 8, 25
    idx = sample_blocks(jax.random.key(seed + 1), X.shape[0], b, iters)

    res_bcd = solve(X, y, lam, b, 1, iters, None, idx=idx, w_ref=w_opt,
                    impl=impl)
    res_ca = solve(X, y, lam, b, s, iters, None, idx=idx, w_ref=w_opt,
                   track_cond=True, impl=impl)

    dev = np.max(np.abs(np.asarray(res_ca.history["objective"]) -
                        np.asarray(res_bcd.history["objective"])))
    print(f"\nBCD      : {iters} iterations -> {iters} synchronizations")
    print(f"CA-BCD   : {iters} iterations -> {iters//s} synchronizations "
          f"(s={s}, one sb x sb Gram each)")
    print(f"max |objective difference| over the whole trajectory: {dev:.2e}")
    print(f"final solution error BCD    : "
          f"{float(res_bcd.history['sol_err'][-1]):.2e}")
    print(f"final solution error CA-BCD : "
          f"{float(res_ca.history['sol_err'][-1]):.2e}")
    print(f"Gram condition numbers (s={s}): median "
          f"{float(np.median(res_ca.history['gram_cond'])):.2f}, max "
          f"{float(np.max(res_ca.history['gram_cond'])):.2f}")
    assert dev < 1e-8, "CA-BCD must match BCD exactly"
    print("\nsame iterates, 1/s the synchronizations -- the paper's claim.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend: ref | pallas | pallas_interpret")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for data + index stream (fixed default "
                         "=> reproducible output)")
    args = ap.parse_args()
    main(args.impl, seed=args.seed)

"""Assertion helpers over the shared HLO parser -- the one API runtime tests
use to pin collective schedules (tests/dist_checks.py), so test assertions and
the contract sweep read the SAME parse of the same text.

``expect_collectives`` asserts an exact count of the allowed kinds and zero
of any other cross-device collective; ``expect_clean`` is the zero-collective
form.  Both accept a jax ``Compiled`` or raw HLO text and raise
``AssertionError`` with the offending op lines (the subprocess checks bubble
these straight to pytest's output).
"""
from __future__ import annotations


def _hlo_text(compiled_or_text) -> str:
    if isinstance(compiled_or_text, str):
        return compiled_or_text
    return compiled_or_text.as_text()


def expect_collectives(compiled_or_text, count: int,
                       kinds: tuple = ("all-reduce",),
                       subject: str = "lowering"):
    """Assert exactly ``count`` collectives of ``kinds`` and none of any
    other kind; returns the parsed ops for further inspection."""
    from repro.core.hlo_analysis import parse_collectives

    ops = parse_collectives(_hlo_text(compiled_or_text))
    allowed = set(kinds)
    stray = [op for op in ops if op.kind not in allowed]
    assert not stray, (
        f"{subject}: {len(stray)} disallowed collective(s) "
        f"(allowed {sorted(allowed)}): "
        + "; ".join(op.line for op in stray[:4]))
    n = sum(1 for op in ops if op.kind in allowed)
    assert n == count, (
        f"{subject}: expected exactly {count} {'+'.join(kinds)}, found {n}: "
        + ("; ".join(op.line.split(' = ')[0] for op in ops) or "<none>"))
    return ops


def expect_clean(compiled_or_text, subject: str = "lowering"):
    """Assert the lowering carries NO cross-device collectives at all."""
    return expect_collectives(compiled_or_text, 0, kinds=(), subject=subject)

"""Kernel plan pass: validate tile plans against hardware limits, statically.

A bad ``PacketPlan`` or autotune-table entry today fails inside a Mosaic
compile (cryptically, on the TPU it first runs on) or silently under-utilizes
VMEM.  This pass checks every plan the repo can dispatch -- the live tuning
table (built-ins + anything merged via ``REPRO_GRAM_TUNING``), the per-layout
heuristic defaults, and any explicit :class:`~repro.kernels.gram.ops.PacketPlan`
a caller hands in -- against constraints computed WITHOUT running a kernel:

* vmem-budget: the static scratch footprint of the layout's Gram/apply
  kernels at (bm, bk) -- ``repro.core.cost_model.kernel_vmem_bytes``, which
  models the declared ``scratch_shapes`` of ``sampled_kernel.py`` /
  ``sampled_colmajor.py`` (the column layout carries the LANE-amplified
  slabs) -- must fit ``cost_model.VMEM_BYTES_PER_CORE``.
* tile-alignment: ``bm`` on the 8-row sublane granule; ``bk`` on the
  128-lane granule for the row layout and the sublane granule for the
  column layout (its contraction runs over X's rows).
* bucket-consistency: a table entry whose tile exceeds its own
  (m_bucket, n_bucket) key can never be returned un-clamped -- dead weight
  that signals a mis-keyed autotune merge.
* index-arithmetic: the scalar-prefetched gather indexes the operand with
  int32; a bucket whose element count exceeds int32 range would overflow
  the kernel's DMA offset arithmetic.
"""
from __future__ import annotations

from .report import PassReport, Violation

_INT32_MAX = 2**31 - 1


def _itemsize(dtype_name: str) -> int:
    return {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2}.get(
        dtype_name, 4)


def check_tiles(bm: int, bk: int, dtype_name: str, layout: str,
                subject: str) -> list:
    """Contract checks for one (bm, bk) tile choice; returns violations."""
    from repro.core import cost_model
    from repro.kernels.gram.tuning import LANE_GRANULE, LAYOUTS, ROW_GRANULE

    out = []
    if layout not in LAYOUTS:
        return [Violation("tile-layout", subject,
                          f"unknown layout {layout!r}, expected {LAYOUTS}")]
    k_granule = LANE_GRANULE if layout == "rows" else ROW_GRANULE
    if bm % ROW_GRANULE:
        out.append(Violation(
            "tile-alignment", subject,
            f"bm={bm} is not a multiple of the {ROW_GRANULE}-row sublane "
            "granule"))
    if bk % k_granule:
        out.append(Violation(
            "tile-alignment", subject,
            f"bk={bk} is not a multiple of the {k_granule}-wide contraction "
            f"granule for layout={layout!r}"))
    need = cost_model.kernel_vmem_bytes(bm, bk, _itemsize(dtype_name),
                                        layout=layout)
    budget = cost_model.VMEM_BYTES_PER_CORE
    if need > budget:
        out.append(Violation(
            "vmem-budget", subject,
            f"(bm={bm}, bk={bk}, {dtype_name}, layout={layout!r}) needs "
            f"{need / 2**20:.1f} MiB of VMEM scratch, budget is "
            f"{budget / 2**20:.1f} MiB"))
    return out


def check_plan(plan, dtype_name: str = "float32",
               layout: str = "rows", subject: str | None = None) -> list:
    """Validate one explicit :class:`PacketPlan` (only pinned knobs are
    checkable; ``None`` tiles defer to the table, which is swept anyway)."""
    from repro.kernels.gram.ops import _IMPLS

    subject = subject or f"PacketPlan(impl={plan.impl}, bm={plan.bm}, bk={plan.bk})"
    out = []
    if plan.impl is not None and plan.impl not in _IMPLS:
        out.append(Violation("plan-impl", subject,
                             f"impl {plan.impl!r} not in {_IMPLS}"))
    if plan.bm is not None and plan.bk is not None:
        out.extend(check_tiles(plan.bm, plan.bk, dtype_name, layout, subject))
    return out


def run_plan_pass(extra_plans=()) -> PassReport:
    """Sweep the live tuning table + heuristic defaults (+ caller plans)."""
    from repro.kernels.gram.tuning import _DEFAULTS, table_entries

    rep = PassReport("plan")
    for (mb, nb, dtype_name, layout), (bm, bk) in table_entries():
        subject = rep.case(f"table[{mb},{nb},{dtype_name},{layout}]"
                           f" -> (bm={bm}, bk={bk})")
        rep.violations.extend(check_tiles(bm, bk, dtype_name, layout, subject))
        if bm > mb or bk > nb:
            rep.violations.append(Violation(
                "bucket-consistency", subject,
                f"tile (bm={bm}, bk={bk}) exceeds its own bucket "
                f"({mb}, {nb}); pick_tiles would always clamp it"))
        if mb * nb > _INT32_MAX:
            rep.violations.append(Violation(
                "index-arithmetic", subject,
                f"bucket holds {mb * nb} elements > int32 max; the "
                "scalar-prefetched gather offsets would overflow"))
    for layout, (bm, bk) in sorted(_DEFAULTS.items()):
        subject = rep.case(f"default[{layout}] -> (bm={bm}, bk={bk})")
        rep.violations.extend(check_tiles(bm, bk, "float32", layout, subject))
    for plan, dtype_name, layout in extra_plans:
        subject = rep.case(f"plan[{plan!r},{dtype_name},{layout}]")
        rep.violations.extend(check_plan(plan, dtype_name, layout, subject))
    return rep

"""Report types for the static contract engine.

Deliberately jax-free: the lint pass and the CLI's argument handling import
this module before any backend exists, and ``ANALYSIS.json`` is produced from
these types alone so CI artifacts do not depend on what compiled.

A :class:`Violation` is one broken contract, named precisely enough to act
on -- ``subject`` identifies the lowering/plan/file, ``message`` names the
offending op or tile.  A :class:`PassReport` is one pass's sweep (how many
cases ran, which were skipped, what broke); :class:`Report` aggregates the
three passes and serializes.
"""
from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class Violation:
    check: str    # contract name, e.g. "collective-count", "vmem-budget"
    subject: str  # case / plan / file:line the contract was checked on
    message: str  # actionable: names the offending HLO op or plan entry

    def __str__(self) -> str:
        return f"[{self.check}] {self.subject}: {self.message}"


@dataclasses.dataclass
class PassReport:
    name: str
    cases: list = dataclasses.field(default_factory=list)      # case names swept
    skipped: list = dataclasses.field(default_factory=list)    # (case, reason)
    violations: list = dataclasses.field(default_factory=list)  # Violation

    @property
    def ok(self) -> bool:
        return not self.violations

    def case(self, name: str) -> str:
        self.cases.append(name)
        return name

    def skip(self, name: str, reason: str) -> None:
        self.skipped.append((name, reason))

    def to_dict(self) -> dict:
        return {
            "name": self.name, "ok": self.ok, "n_cases": len(self.cases),
            "cases": list(self.cases),
            "skipped": [{"case": c, "reason": r} for c, r in self.skipped],
            "violations": [dataclasses.asdict(v) for v in self.violations],
        }


@dataclasses.dataclass
class Report:
    passes: list = dataclasses.field(default_factory=list)  # PassReport
    meta: dict = dataclasses.field(default_factory=dict)    # versions, shapes

    @property
    def ok(self) -> bool:
        return all(p.ok for p in self.passes)

    @property
    def violations(self) -> list:
        return [v for p in self.passes for v in p.violations]

    def to_dict(self) -> dict:
        return {"ok": self.ok, "meta": dict(self.meta),
                "passes": [p.to_dict() for p in self.passes]}

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    def summary(self) -> str:
        lines = []
        for p in self.passes:
            status = "ok" if p.ok else f"{len(p.violations)} violation(s)"
            extra = f", {len(p.skipped)} skipped" if p.skipped else ""
            lines.append(f"{p.name}: {len(p.cases)} case(s){extra} -- {status}")
            lines.extend(f"  {v}" for v in p.violations)
        lines.append("ANALYSIS " + ("PASS" if self.ok else "FAIL"))
        return "\n".join(lines)

"""CLI driver: ``python -m repro.analysis {sweep,lint}``.

``sweep`` runs all three passes (HLO contracts, kernel plans, convention
lint), writes ``ANALYSIS.json``, prints a summary, and exits nonzero on any
violation -- the CI ``contracts`` job and ``make check-contracts`` both run
exactly this.  ``lint`` runs the AST pass alone (no jax import, usable as a
pre-commit hook).

XLA_FLAGS is set BEFORE any jax import (the package __init__ is lazy for
this reason): the HLO pass needs a multi-device host platform to lower the
sharded backends, 8 forced host devices by default (override by exporting
XLA_FLAGS yourself -- setdefault keeps a caller's choice).
"""
from __future__ import annotations

import argparse
import os
import sys

# Must precede any jax import anywhere in the process (run_hlo_pass imports
# jax lazily, so setting it here is early enough for `python -m`).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from .report import Report  # noqa: E402  (jax-free)


def run_sweep(formulations=None) -> Report:
    """All three passes -> one Report (importable; the tests drive this)."""
    from .hlo_pass import run_hlo_pass
    from .lint import run_lint
    from .plan_pass import run_plan_pass

    import jax

    report = Report(meta={
        "jax_version": jax.__version__,
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
    })
    report.passes.append(run_hlo_pass(formulations=formulations))
    report.passes.append(run_plan_pass())
    report.passes.append(run_lint(repo_root=os.getcwd()))
    return report


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract engine (DESIGN.md section 6)")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_sweep = sub.add_parser(
        "sweep", help="all three passes over the solver registry")
    p_sweep.add_argument("-o", "--output", default="ANALYSIS.json",
                         help="report path (default: ANALYSIS.json)")
    p_sweep.add_argument("--formulation", action="append", default=None,
                         help="restrict to one formulation (repeatable)")

    p_lint = sub.add_parser("lint", help="convention lint pass only (no jax)")
    p_lint.add_argument("paths", nargs="*", default=None,
                        help="files/trees to lint (default: src scripts "
                             "examples benchmarks)")

    args = parser.parse_args(argv)

    if args.cmd == "lint":
        from .lint import run_lint
        rep = run_lint(paths=args.paths or None, repo_root=os.getcwd())
        report = Report(passes=[rep])
        print(report.summary())
        return 0 if report.ok else 1

    report = run_sweep(formulations=args.formulation)
    with open(args.output, "w", encoding="utf-8") as f:
        f.write(report.to_json() + "\n")
    print(report.summary())
    print(f"report written to {args.output}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""repro.analysis -- the static contract engine (DESIGN.md section 6).

Three passes over everything the registry can dispatch, none of which runs a
solver: the HLO contract pass (``hlo_pass``) lowers every registered
(formulation, backend, impl, fuse_packet, ragged) combination and asserts the
contracts each formulation declares via ``contracts()``; the kernel plan pass
(``plan_pass``) validates every tuning-table entry and PacketPlan against
VMEM/alignment/index-width limits; the convention lint pass (``lint``)
enforces the AST-level repo rules ruff cannot express.

CLI: ``python -m repro.analysis sweep`` (all three passes -> ANALYSIS.json)
and ``python -m repro.analysis lint`` (lint only, jax-free).

This ``__init__`` is import-light on purpose (PEP 562 lazy exports): the CLI
must be able to set ``XLA_FLAGS`` before anything imports jax, and the lint
pass must run in environments without jax at all.
"""
from __future__ import annotations

_LAZY = {
    "Report": "report", "PassReport": "report", "Violation": "report",
    "run_hlo_pass": "hlo_pass",
    "run_plan_pass": "plan_pass", "check_tiles": "plan_pass",
    "check_plan": "plan_pass",
    "run_lint": "lint", "lint_file": "lint",
    "run_sweep": "__main__",
    "expect_collectives": "api", "expect_clean": "api",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

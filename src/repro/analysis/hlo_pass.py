"""HLO contract pass: lower every registered solver, check declared contracts.

For each formulation in the registry this pass lowers the solver over the
full configuration matrix -- backend (local/sharded), impl (ref /
pallas_interpret), fuse_packet (True/False), even and ragged iteration counts
-- on ABSTRACT inputs (no math runs; only XLA does), parses the compiled HLO
through ``repro.core.hlo_analysis``, and asserts the contracts the
formulation DECLARES via its ``contracts()`` hook
(:class:`repro.core.engine.SolverContracts`):

* collective-count: the sharded lowering carries exactly
  ``sync_per_outer * H`` collectives, all of the declared kinds
  (``H = iters//s + (iters % s != 0)`` -- the paper's one-reduction-per-
  outer-iteration claim, ragged tail included).  Lowered at
  ``unroll = iters // s`` so the scanned outer loop is fully unrolled and
  the static op count equals the dynamic one.
* local-collective-free: the local backend lowers to ZERO collectives.
* operand-transpose-free: no ``transpose`` op whose result is the local
  operand shape (either orientation) -- the PR-5 "dual binds the original
  layout" guarantee.  Checked on sharded lowerings (the local metrics path
  legitimately reads ``X.T @ w``; see the allow-transpose waivers).
* panel-free: for impls in ``panel_free_impls``, no gather/fusion op
  materializes the (sb, contraction) sampled panel -- the PR-2 guarantee
  that only the ref impl builds ``Y = X[idx]``.
* f64-packet: under the x64 config every collective carries f64 (one extra
  sharded lowering per formulation, at dtype=float64).
* health-in-packet: for formulations declaring ``health_in_packet``, the
  guard-armed lowering (``guard=True``) obeys the SAME collective budget --
  exactly ``sync_per_outer * H`` sharded, zero local -- proving the health
  word rides the packet psum instead of adding a reduction (the PR-7
  zero-extra-collectives guarantee; 2 extra local + 4 extra sharded ref
  cases per formulation).
* pipelined wire schedule: the ``"pipelined"`` backend's ring decomposition
  lowers to exactly ``H * ring_hops(mesh)`` collectives of the DECLARED
  ``pipelined_collective_kinds`` (collective-permute) and zero of anything
  else -- in particular zero all-reduces: the monolithic psum is fully
  replaced, not augmented.  The hop count comes from the contract's
  ``pipelined_hops`` affine law ``sum_i (a*P_i + c)``, not a hand-edited
  constant, and the guard-armed and tenant-batched lowerings must obey the
  SAME budget (health word and tenant payload ride the decomposed
  reduction).

Sweep shapes are chosen so the shapes the checks key on are PAIRWISE
DISTINCT (sb=8, d/P=16, n/P=32, d=16P, n=32P): a square sb x sb transpose
from the symmetric-skip Gram mirror can never alias the operand shape, and a
(bm, bk) kernel tile can alias the panel only when it IS the panel.
"""
from __future__ import annotations

from .report import PassReport, Violation

# Sweep geometry (per device count P, fixed at run time): every shape class
# distinct, ragged tail exercised by ITERS_RAGGED % S != 0.
B, S = 4, 2
ITERS_EVEN, ITERS_RAGGED = 4, 3
D_PER_P, N_PER_P = 16, 32
IMPLS = ("ref", "pallas_interpret")


def _outer_count(iters: int, s: int) -> int:
    return iters // s + (1 if iters % s else 0)


def _contracts_of(form):
    from repro.core.engine import SolverContracts
    hook = getattr(form, "contracts", None)
    return hook() if hook is not None else SolverContracts()


def _panel_shapes(sb: int, contraction: int) -> set:
    return {(sb, contraction), (contraction, sb)}


def _check_collectives(txt, contract, expected, subject, violations):
    """Count + kind check through the one shared parser."""
    from repro.core.hlo_analysis import parse_collectives
    ops = parse_collectives(txt)
    allowed = set(contract.collective_kinds)
    for op in ops:
        if op.kind not in allowed:
            violations.append(Violation(
                "collective-kind", subject,
                f"disallowed {op.kind} (declared kinds {sorted(allowed)}): "
                f"{op.line}"))
    n = sum(1 for op in ops if op.kind in allowed)
    if n != expected:
        lines = "; ".join(op.line.split(" = ")[0] for op in ops) or "<none>"
        violations.append(Violation(
            "collective-count", subject,
            f"expected exactly {expected} collective(s) "
            f"({'+'.join(contract.collective_kinds)}), found {n}: {lines}"))


def _check_no_transpose(txt, operand_shape, subject, violations):
    from repro.core.hlo_analysis import parse_named_ops
    bad = {tuple(operand_shape), tuple(reversed(operand_shape))}
    for op in parse_named_ops(txt, opcodes=("transpose",)):
        for shape in op.shapes():
            if shape in bad:
                violations.append(Violation(
                    "operand-transpose", subject,
                    f"transpose materializes the operand layout "
                    f"{shape}: {op.line}"))


def _check_panel_free(txt, sb, contraction, subject, violations):
    """A materialized ``Y = X[idx]`` lowers to a panel-shaped ``gather`` op
    (or a fusion XLA names after the gather it absorbed, e.g.
    ``%bitcast_gather_fusion``).  The kernels' interpret-mode scratch
    emulation also carries panel-shaped tiles at these tiny sweep shapes,
    but those are dynamic-(update-)slice fusions -- no gather -- so keying
    on the gather distinguishes "materialized the panel" from "the tile
    covers the whole panel"."""
    from repro.core.hlo_analysis import parse_named_ops
    bad = _panel_shapes(sb, contraction)
    for op in parse_named_ops(txt, opcodes=("gather", "fusion")):
        if op.opcode == "fusion" and "gather" not in op.result_name:
            continue
        for shape in op.shapes():
            if shape in bad:
                violations.append(Violation(
                    "panel-materialized", subject,
                    f"{op.opcode} materializes the ({sb}, {contraction}) "
                    f"sampled panel outside the kernel: {op.line}"))


def _case_geometry(form, P):
    """(d, n, sb, local operand shape, local contraction length)."""
    d, n = D_PER_P * P, N_PER_P * P
    sb = S * B
    if form.operand_layout == "rows":          # primal family: shard columns
        op_shape, contraction = (d, n // P), n // P
    else:                                      # dual: shard rows
        op_shape, contraction = (d // P, n), d // P
    return d, n, sb, op_shape, contraction


TENANTS_SWEPT = (1, 8, 64)


def _check_batched_contract(name, contract, mesh, d, n, sb, rep):
    """DESIGN.md section 8, machine-checked: the T-tenant sharded lowering
    emits exactly ``sync_per_outer * H`` all-reduces for every T -- the
    tenant axis adds ZERO sync points -- and the per-step wire payload is
    ``sb^2 + T*sb`` words, i.e. the Gram part is NOT scaled by T (only the
    (T, sb) per-tenant residual directions ride along).  The payload law is
    asserted exactly: ``bytes(T) == bytes(1) + (T-1)*sb*word*H``."""
    from repro.core.distributed import lower_solver_batched
    from repro.core.hlo_analysis import collective_summary

    coeff_names = tuple(k for k, _ in contract.lowering_kwargs)
    word = 4                       # the sweep lowers at dtype=float32
    payload = {}
    for tenants in TENANTS_SWEPT:
        iters_list = (ITERS_EVEN, ITERS_RAGGED) if tenants == 8 \
            else (ITERS_EVEN,)     # ragged tail once; T-sweep at even iters
        for iters in iters_list:
            case = rep.case(f"{name}/batched[T={tenants},iters={iters}]")
            compiled = lower_solver_batched(
                name, mesh, d, n, tenants, B, S, iters,
                unroll=max(iters // S, 1), coeff_names=coeff_names)
            txt = compiled.as_text()
            H = _outer_count(iters, S)
            _check_collectives(txt, contract, contract.sync_per_outer * H,
                               case, rep.violations)
            if iters == ITERS_EVEN:
                payload[tenants] = collective_summary(txt).operand_bytes
    H = _outer_count(ITERS_EVEN, S)
    base = payload[TENANTS_SWEPT[0]]
    for tenants in TENANTS_SWEPT[1:]:
        want = base + (tenants - 1) * sb * word * H
        if payload[tenants] != want:
            rep.violations.append(Violation(
                "gram-payload-scaled", f"{name}/batched[T={tenants}]",
                f"wire payload {payload[tenants]:.0f}B != "
                f"{want:.0f}B (= T=1 payload + (T-1)*sb*word*H): the "
                f"shared sb x sb Gram must not scale with the tenant axis"))


def run_hlo_pass(formulations=None) -> PassReport:
    """Sweep the solver registry; returns the pass report.

    Requires >= 2 jax devices for the sharded matrix (the CLI forces 8 host
    devices); sharded cases are recorded as skipped otherwise.
    """
    import jax

    import repro.core  # noqa: F401  (imports register the built-in solvers)
    from repro.core.distributed import (lower_solver, lower_solver_local,
                                        make_solver_mesh)
    from repro.core.engine import FORMULATIONS, registered_solvers
    from repro.core.hlo_analysis import collective_dtypes

    rep = PassReport("hlo")
    lam = 1e-3
    P = len(jax.devices())
    mesh = make_solver_mesh() if P > 1 else None
    backends = {name: set() for name in FORMULATIONS}
    for name, backend in registered_solvers():
        backends.setdefault(name, set()).add(backend)
    names = sorted(formulations) if formulations else sorted(backends)

    for name in names:
        form = FORMULATIONS[name]
        contract = _contracts_of(form)
        kw = dict(contract.lowering_kwargs)
        d, n, sb, op_shape, contraction = _case_geometry(form, max(P, 1))

        # ---- local backend: must lower to zero collectives ----------------
        if "local" in backends.get(name, ()):
            for impl in IMPLS:
                for iters in (ITERS_EVEN, ITERS_RAGGED):
                    case = rep.case(f"{name}/local[impl={impl},iters={iters}]")
                    compiled = lower_solver_local(
                        name, d, n, lam, B, S, iters, impl=impl, **kw)
                    txt = compiled.as_text()
                    if contract.local_collective_free:
                        _check_collectives(txt, contract, 0, case,
                                           rep.violations)
                    if impl in contract.panel_free_impls:
                        _check_panel_free(txt, sb, n if form.operand_layout
                                          == "rows" else d, case,
                                          rep.violations)
            if contract.health_in_packet:
                # Guard-armed local lowerings stay collective-free (ref impl
                # only: the guard is impl-independent post-kernel logic).
                for iters in (ITERS_EVEN, ITERS_RAGGED):
                    case = rep.case(
                        f"{name}/local[impl=ref,iters={iters},guard]")
                    compiled = lower_solver_local(
                        name, d, n, lam, B, S, iters, impl="ref", guard=True,
                        **kw)
                    _check_collectives(compiled.as_text(), contract, 0, case,
                                       rep.violations)

        # ---- sharded backend: H collectives, no operand transpose ---------
        if "sharded" in backends.get(name, ()):
            if mesh is None:
                rep.skip(f"{name}/sharded", "needs >= 2 devices")
                continue
            for impl in IMPLS:
                for fuse in (True, False):
                    for iters in (ITERS_EVEN, ITERS_RAGGED):
                        case = rep.case(
                            f"{name}/sharded[impl={impl},fuse={fuse},"
                            f"iters={iters}]")
                        compiled = lower_solver(
                            name, mesh, d, n, lam, B, S, iters,
                            fuse_packet=fuse, impl=impl,
                            unroll=max(iters // S, 1), **kw)
                        txt = compiled.as_text()
                        H = _outer_count(iters, S)
                        _check_collectives(txt, contract,
                                           contract.sync_per_outer * H,
                                           case, rep.violations)
                        if contract.operand_transpose_free:
                            _check_no_transpose(txt, op_shape, case,
                                                rep.violations)
                        if impl in contract.panel_free_impls:
                            _check_panel_free(txt, sb, contraction, case,
                                              rep.violations)

            # ---- guard armed: the health word MUST ride the packet psum ----
            if contract.health_in_packet:
                for fuse in (True, False):
                    for iters in (ITERS_EVEN, ITERS_RAGGED):
                        case = rep.case(
                            f"{name}/sharded[impl=ref,fuse={fuse},"
                            f"iters={iters},guard]")
                        compiled = lower_solver(
                            name, mesh, d, n, lam, B, S, iters,
                            fuse_packet=fuse, impl="ref",
                            unroll=max(iters // S, 1), guard=True, **kw)
                        txt = compiled.as_text()
                        H = _outer_count(iters, S)
                        _check_collectives(txt, contract,
                                           contract.sync_per_outer * H,
                                           case, rep.violations)
                        if contract.operand_transpose_free:
                            _check_no_transpose(txt, op_shape, case,
                                                rep.violations)

            # ---- tenant-batched: H all-reduces INDEPENDENT of T -----------
            if contract.tenant_batched:
                _check_batched_contract(name, contract, mesh, d, n, sb, rep)

            # ---- one x64 lowering: the packet must reduce in f64 ----------
            if contract.f64_packet:
                case = rep.case(f"{name}/sharded[x64]")
                x64_was = jax.config.jax_enable_x64
                jax.config.update("jax_enable_x64", True)
                try:
                    import jax.numpy as jnp
                    compiled = lower_solver(
                        name, mesh, d, n, lam, B, S, ITERS_EVEN,
                        dtype=jnp.float64, unroll=ITERS_EVEN // S, **kw)
                    dts = collective_dtypes(compiled.as_text())
                finally:
                    jax.config.update("jax_enable_x64", x64_was)
                if dts != {"f64"}:
                    rep.violations.append(Violation(
                        "f64-packet", case,
                        f"x64 lowering reduces in {sorted(dts)}, expected "
                        "all collectives to carry f64"))

        # ---- pipelined backend: H * ring_hops declared-kind collectives ---
        if "pipelined" in backends.get(name, ()):
            if mesh is None:
                rep.skip(f"{name}/pipelined", "needs >= 2 devices")
                continue
            import dataclasses

            from repro.core.distributed import lower_solver_batched
            from repro.core.engine import ring_hops

            # The schedule the backend DECLARES: collective-permute hops,
            # counted by the contract's affine law over the mesh axis sizes.
            ring_contract = dataclasses.replace(
                contract,
                collective_kinds=contract.pipelined_collective_kinds)
            hops = ring_hops(tuple(mesh.shape.values()),
                             law=contract.pipelined_hops)
            for impl in IMPLS:
                for iters in (ITERS_EVEN, ITERS_RAGGED):
                    case = rep.case(
                        f"{name}/pipelined[impl={impl},iters={iters}]")
                    compiled = lower_solver(
                        name, mesh, d, n, lam, B, S, iters, impl=impl,
                        unroll=max(iters // S, 1), backend="pipelined", **kw)
                    txt = compiled.as_text()
                    H = _outer_count(iters, S)
                    _check_collectives(txt, ring_contract, hops * H, case,
                                       rep.violations)
                    if contract.operand_transpose_free:
                        _check_no_transpose(txt, op_shape, case,
                                            rep.violations)
                    if impl in contract.panel_free_impls:
                        _check_panel_free(txt, sb, contraction, case,
                                          rep.violations)
            if contract.health_in_packet:
                # health word rides the decomposed reduction: same budget
                for iters in (ITERS_EVEN, ITERS_RAGGED):
                    case = rep.case(
                        f"{name}/pipelined[impl=ref,iters={iters},guard]")
                    compiled = lower_solver(
                        name, mesh, d, n, lam, B, S, iters, impl="ref",
                        unroll=max(iters // S, 1), guard=True,
                        backend="pipelined", **kw)
                    H = _outer_count(iters, S)
                    _check_collectives(compiled.as_text(), ring_contract,
                                       hops * H, case, rep.violations)
            if contract.tenant_batched:
                # tenant payload rides the decomposed reduction: same budget
                coeff_names = tuple(k for k, _ in contract.lowering_kwargs)
                for iters in (ITERS_EVEN, ITERS_RAGGED):
                    case = rep.case(
                        f"{name}/pipelined-batched[T=8,iters={iters}]")
                    compiled = lower_solver_batched(
                        name, mesh, d, n, 8, B, S, iters,
                        unroll=max(iters // S, 1), coeff_names=coeff_names,
                        wire="ring")
                    H = _outer_count(iters, S)
                    _check_collectives(compiled.as_text(), ring_contract,
                                       hops * H, case, rep.violations)
    return rep

"""Convention lint pass: AST checks for rules ruff cannot express.

Three repo rules, each with a comment-waiver escape hatch (``# contract:
allow-<rule>`` on the offending line or the line above -- a waiver is a
reviewed, documented exception, not a hole):

* raw-collective: ``jax.lax.psum``/``pmax``/... and ``shard_map`` may be
  CALLED only in ``core/engine.py`` (the solvers' single communication
  point, ``_packet_reduce``) and ``repro/compat.py`` (the version shim).
  Anything else either routes through the engine or carries an
  ``allow-collective`` waiver (e.g. the flash-decode layer, whose fused
  softmax reduction is deliberately its own communication point).
* operand-transpose: inside classes that implement the Formulation/bound
  hooks (``bind``/``bind_shard``/``packet_vector``/``update``/
  ``inner_sweep``/``init_carry``/``metrics``), no ``.T`` -- the PR-5 rule
  that operands bind in their ORIGINAL layout and all transposition lives
  in the PacketOperand gather strategy.  Warm-start/metrics uses carry
  ``allow-transpose`` waivers.
* env-before-jax: a module that sets ``os.environ["XLA_FLAGS"]`` at module
  level must do so BEFORE its first module-level jax import -- after the
  backend initializes, the flag is read-once dead (device counts silently
  wrong, the classic 1-device "distributed" test).

Pure stdlib (ast + tokenize-free line scan): runs without jax installed,
which keeps ``python -m repro.analysis lint`` usable as a pre-commit hook.
"""
from __future__ import annotations

import ast
import os

from .report import PassReport, Violation

COLLECTIVE_CALLS = frozenset({
    "psum", "pmax", "pmin", "pmean", "all_gather", "psum_scatter",
    "ppermute", "all_to_all"})
# Files where raw collectives ARE the design (path suffixes, POSIX form).
COLLECTIVE_ALLOWLIST = ("repro/core/engine.py", "repro/compat.py")
# A class is "formulation-shaped" if it defines any of these hooks.
FORMULATION_HOOKS = frozenset({
    "bind", "bind_shard", "packet_vector", "update", "inner_sweep",
    "init_carry", "metrics", "dist_in_specs"})
DEFAULT_ROOTS = ("src/repro", "scripts", "examples", "benchmarks")


def _waived(lines: list, lineno: int, rule: str) -> bool:
    """Waiver on the offending line, or anywhere in the contiguous comment
    block immediately above it (waivers read best as a short explanation)."""
    tag = f"contract: allow-{rule}"
    if 1 <= lineno <= len(lines) and tag in lines[lineno - 1]:
        return True
    ln = lineno - 1
    while 1 <= ln <= len(lines) and lines[ln - 1].lstrip().startswith("#"):
        if tag in lines[ln - 1]:
            return True
        ln -= 1
    return False


def _attr_chain(node) -> list:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_collective_call(call: ast.Call) -> str | None:
    chain = _attr_chain(call.func)
    if not chain:
        return None
    if chain[-1] == "shard_map":
        return "shard_map"
    if chain[-1] in COLLECTIVE_CALLS and "lax" in chain[:-1]:
        return ".".join(chain)
    return None


def _check_collectives(tree, lines, relpath, violations):
    if relpath.endswith(COLLECTIVE_ALLOWLIST):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _is_collective_call(node)
        if name and not _waived(lines, node.lineno, "collective"):
            violations.append(Violation(
                "raw-collective", f"{relpath}:{node.lineno}",
                f"raw {name} call outside core/engine.py -- route the "
                "reduction through the engine's packet, or waive with "
                "'# contract: allow-collective'"))


def _check_transposes(tree, lines, relpath, violations):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {n.name for n in cls.body if isinstance(
            n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if not methods & FORMULATION_HOOKS:
            continue
        for node in ast.walk(cls):
            if (isinstance(node, ast.Attribute) and node.attr == "T"
                    and not _waived(lines, node.lineno, "transpose")):
                violations.append(Violation(
                    "operand-transpose", f"{relpath}:{node.lineno}",
                    f"'.T' inside formulation class {cls.name} -- operands "
                    "bind in their original layout (the PacketOperand owns "
                    "the gather); waive with '# contract: allow-transpose'"))


def _is_jax_import(node) -> bool:
    if isinstance(node, ast.Import):
        return any(a.name == "jax" or a.name.startswith("jax.")
                   for a in node.names)
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        return mod == "jax" or mod.startswith("jax.")
    return False


def _xla_flags_lineno(node) -> int | None:
    """Module-level statement that writes os.environ['XLA_FLAGS'] (assign or
    .setdefault), else None."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Subscript):
            if (_attr_chain(sub.value)[-2:] == ["os", "environ"]
                    or _attr_chain(sub.value) == ["environ"]):
                key = sub.slice
                if isinstance(key, ast.Constant) and key.value == "XLA_FLAGS":
                    if isinstance(getattr(sub, "ctx", None), ast.Store):
                        return sub.lineno
        if isinstance(sub, ast.Call):
            chain = _attr_chain(sub.func)
            if chain[-1:] == ["setdefault"] and "environ" in chain:
                if (sub.args and isinstance(sub.args[0], ast.Constant)
                        and sub.args[0].value == "XLA_FLAGS"):
                    return sub.lineno
    return None


def _check_env_order(tree, lines, relpath, violations):
    first_jax = None
    for node in tree.body:  # module level only: function bodies run later
        if first_jax is None and _is_jax_import(node):
            first_jax = node.lineno
        ln = _xla_flags_lineno(node)
        if ln is not None and first_jax is not None:
            if not _waived(lines, ln, "env-order"):
                violations.append(Violation(
                    "env-before-jax", f"{relpath}:{ln}",
                    f"XLA_FLAGS set after 'import jax' (line {first_jax}) "
                    "-- the backend has already initialized, the flag is "
                    "dead; set it before the import"))


def iter_py_files(paths):
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = [d for d in dirs
                       if not d.startswith(".") and d != "__pycache__"]
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_file(path: str, repo_root: str | None = None) -> list:
    relpath = os.path.relpath(path, repo_root) if repo_root else path
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation("parse-error", f"{relpath}:{e.lineno}", str(e))]
    lines = src.splitlines()
    violations: list = []
    _check_collectives(tree, lines, relpath, violations)
    _check_transposes(tree, lines, relpath, violations)
    _check_env_order(tree, lines, relpath, violations)
    return violations


def run_lint(paths=None, repo_root: str | None = None) -> PassReport:
    """Lint the given files/trees (default: the repo's source trees)."""
    if paths is None:
        root = repo_root or os.getcwd()
        paths = [os.path.join(root, p) for p in DEFAULT_ROOTS
                 if os.path.exists(os.path.join(root, p))]
    rep = PassReport("lint")
    for path in iter_py_files(paths):
        rep.case(os.path.relpath(path, repo_root) if repo_root else path)
        rep.violations.extend(lint_file(path, repo_root))
    return rep

"""Tall-Skinny QR (TSQR) baseline (paper Table 2 / Figure 1, ref. [14]).

Binary-tree QR over row panels: each leaf computes a local Householder QR,
adjacent R factors are stacked and re-factored up the tree -- log2(P) stages,
a single reduction in the distributed setting (the paper's "single message"
point in Figure 1c).  We use it to solve ridge via the stable semi-normal
equations: QR of the regularized tall matrix A = [X^T/sqrt(n); sqrt(lam) I]
gives R with A^T A = R^T R, then two triangular solves.  For d > n the dual
form is used so the panel stays tall and skinny (cost min(d,n)^2 max(d,n)).

``cholqr_r`` is the Gram-routed alternative: R from the Cholesky factor of
the c x c Gram A^T A, built by the same dispatch layer
(``repro.kernels.gram.gram``) the solvers use -- one Gram + one local
factorization, the CholeskyQR communication pattern (also a single reduction;
stable here because ridge always factors the lam-regularized operator).
``tsqr_ridge(method="cholqr", impl=...)`` solves through it, so the R-factor
Gram runs on the Pallas backend when ``impl`` selects it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.kernels.gram import gram


def _pad_rows(A: jax.Array, rows: int) -> jax.Array:
    pad = rows - A.shape[0]
    if pad <= 0:
        return A
    return jnp.concatenate([A, jnp.zeros((pad, A.shape[1]), A.dtype)], axis=0)


def tsqr(A: jax.Array, n_blocks: int = 8) -> jax.Array:
    """Return the R factor of A (tall, m >= c) via a binary reduction tree.

    ``n_blocks`` plays the role of P leaf processors; it is rounded up to a
    power of two.  Equivalent (up to row signs) to jnp.linalg.qr(A)[1]; the
    sign ambiguity cancels in R^T R, which is all the ridge solve consumes.
    """
    m, c = A.shape
    nb = 1
    while nb < n_blocks:
        nb *= 2
    rows = -(-m // nb) * nb
    A = _pad_rows(A, rows)
    panels = A.reshape(nb, rows // nb, c)

    # Leaf QRs.  Local panels must be at least c tall for a square R; pad if not.
    leaf_rows = max(rows // nb, c)
    panels = jax.vmap(lambda p: _pad_rows(p, leaf_rows))(panels)
    rs = jax.vmap(lambda p: jnp.linalg.qr(p, mode="r"))(panels)  # (nb, c, c)

    # Reduction tree: stack sibling Rs and re-factor.
    while rs.shape[0] > 1:
        half = rs.shape[0] // 2
        stacked = jnp.concatenate([rs[:half], rs[half:]], axis=1)  # (half, 2c, c)
        rs = jax.vmap(lambda p: jnp.linalg.qr(p, mode="r"))(stacked)
    return rs[0]


def cholqr_r(A: jax.Array, *, impl: str | None = None) -> jax.Array:
    """R factor of tall A (m >= c) via CholeskyQR: R^T R = A^T A, with the
    Gram built by the dispatch layer (``gram(A.T)`` -- the kernel backend on
    TPU when ``impl`` selects it).  Same single-reduction communication
    pattern as TSQR; numerically safe on the ridge path because the operand
    carries the sqrt(lam) regularizer rows."""
    G = gram(A.T, impl=impl)                       # c x c = A^T A
    return jnp.linalg.cholesky(G.astype(A.dtype)).T  # upper triangular


def tsqr_ridge(X: jax.Array, y: jax.Array, lam: float, n_blocks: int = 8,
               method: str = "tsqr", impl: str | None = None) -> jax.Array:
    """Ridge solve via TSQR (stable implicit normal equations) or CholeskyQR
    (``method="cholqr"``: the R-factor Gram routed through the Gram-backend
    dispatch layer, ``impl`` selecting ref/pallas)."""
    if method not in ("tsqr", "cholqr"):
        raise ValueError(f"unknown method {method!r}; expected tsqr|cholqr")

    def r_factor(A):
        if method == "cholqr":
            return cholqr_r(A, impl=impl)
        return tsqr(A, n_blocks)

    d, n = X.shape
    sqlam = jnp.sqrt(jnp.asarray(lam, X.dtype))
    if d <= n:
        A = jnp.concatenate([X.T / jnp.sqrt(jnp.asarray(n, X.dtype)),
                             sqlam * jnp.eye(d, dtype=X.dtype)], axis=0)
        R = r_factor(A)
        rhs = X @ y / n
        z = jsl.solve_triangular(R.T, rhs, lower=True)
        return jsl.solve_triangular(R, z, lower=False)
    # Dual path: w = X (X^T X / n + lam I)^{-1} y / n.
    A = jnp.concatenate([X / jnp.sqrt(jnp.asarray(n, X.dtype)),
                         sqlam * jnp.eye(n, dtype=X.dtype)], axis=0)
    R = r_factor(A)
    z = jsl.solve_triangular(R.T, y, lower=True)
    z = jsl.solve_triangular(R, z, lower=False)
    return X @ z / n

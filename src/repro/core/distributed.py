"""Distributed (CA-)BCD / (CA-)BDCD via shard_map + jax.lax collectives.

Layouts follow the paper's analysis (section 4):

* (CA-)BCD : 1D-block-column -- X's data-point axis (n) sharded, vectors in
  R^n sharded, vectors in R^d replicated.  The Gram of sampled *rows* then
  needs one psum over the column axis per Gram (Theorems 1/6).
* (CA-)BDCD: 1D-block-row -- X's feature axis (d) sharded, vectors in R^d
  sharded, vectors in R^n replicated (Theorems 2/7).

Communication structure (the paper's claim, verified by HLO count in tests):

  classical:  2 all-reduces per iteration      (Gram; residual)
  classical fused: 1 all-reduce per iteration  (ours: Gram || residual packet)
  CA(s):      2 all-reduces per s iterations
  CA(s) fused: 1 all-reduce per s iterations   (default)

The fused packet is a beyond-paper optimization: the sb x sb Gram and the
sb-vector residual contribution are concatenated into ONE sb x (sb+1) operand
so each outer iteration has exactly one synchronization event on the wire.
``fuse_packet=False`` reproduces the paper's two-reduction schedule for the
faithful baseline measured in EXPERIMENTS.md section Perf.

All devices compute identical block indices from the replicated key (the
paper's shared-seed trick), so the overlap terms and the inner block forward
substitution are local and replicated.

The local (G, r) contributions are built panel-free by the Gram-backend
dispatch layer (``repro.kernels.gram.gram_packet_sampled``): each shard hands
the kernel its local X shard plus the replicated block indices, and the
sampled rows are gathered inside the kernel (scalar-prefetched indices, rows
DMA'd HBM->VMEM on TPU; jnp gather on the CPU reference).  The local sampled
panel ``Yl`` is never materialized -- the deferred vector updates
(``al += Yl^T dws`` / ``wl -= Yl das``) run through ``panel_apply`` on the
same (shard, indices) pair.  The dual layout pre-transposes its shard once,
outside the scan, so column sampling becomes row sampling -- at the cost of
2x the shard's resident footprint while the solve runs (see the memory note
in ``repro.core.bdcd``).  ``impl=`` selects the backend per solver; mesh
construction and shard_map go through ``repro.compat`` so the same code runs
on JAX 0.4.37 and newer API generations.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.kernels.gram import gram_packet_sampled, panel_apply

from .bcd import _tile_kw
from .sampling import overlap_matrix, sample_blocks
from .subproblem import block_forward_substitution, solve_spd


def make_solver_mesh(n_devices: int | None = None, name: str = "shards") -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    return compat.make_mesh((n,), (name,))


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple of ``mult``.  Zero rows/columns
    of X contribute nothing to Grams, residuals or updates, and the sampler
    only draws indices < the true size, so padding is exact (tested)."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _axes(axis) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


def _pvary(x, axis):
    """Mark a locally-created array as device-varying over ``axis`` (scan-carry
    vma bookkeeping inside shard_map; no-op on pre-vma JAX)."""
    return compat.pvary(x, _axes(axis))


def _psum_packet(G_local, r_local, axis, fuse):
    sb = G_local.shape[0]
    if fuse:
        packet = jax.lax.psum(
            jnp.concatenate([G_local, r_local[:, None]], axis=1), axis)
        return packet[:, :sb], packet[:, sb]
    return jax.lax.psum(G_local, axis), jax.lax.psum(r_local, axis)


# --------------------------------------------------------------------------
# Primal: 1D-block-column
# --------------------------------------------------------------------------

def ca_bcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                   s: int, iters: int, key: jax.Array, *,
                   axis: str = "shards", fuse_packet: bool = True,
                   idx: jax.Array | None = None, unroll: int = 1,
                   impl: str | None = None,
                   tiles: tuple[int, int] | None = None):
    """CA-BCD with X (d, n) sharded over columns.  s=1 gives the classical
    schedule (one Gram reduction per iteration).  Returns (w replicated,
    alpha sharded over n).  ``impl`` selects the Gram-packet backend for the
    local (G, r) contributions (see ``repro.kernels.gram``); ``tiles`` pins
    the kernel's (bm, bk) instead of the autotuned pick."""
    d, n = X.shape
    if iters % s:
        raise ValueError(f"iters={iters} must be a multiple of s={s}")
    if idx is None:
        idx = sample_blocks(key, d, b, iters)
    idx = idx.reshape(iters // s, s, b)
    sb = s * b
    dtype = X.dtype
    tk = _tile_kw(tiles)
    n_shards = math.prod(mesh.shape[a] for a in _axes(axis))
    X = _pad_to(X, n_shards, axis=1)
    y = _pad_to(y, n_shards, axis=0)

    def body(Xl, yl, idx_rep):
        w = jnp.zeros((d,), dtype)
        # alpha is device-varying (each shard owns a slice of R^n); mark the
        # initial zeros as varying over the mesh axis for the scan carry.
        al = _pvary(jnp.zeros(yl.shape, dtype), axis)

        def outer(carry, idx_k):
            w, al = carry
            # Local (Gram, residual) contribution, panel-free: the sampled
            # rows of the local shard are gathered inside the kernel; reg
            # stays 0 here -- the regularizer is added once, after the psum.
            flat = idx_k.reshape(sb)
            Gl, rl = gram_packet_sampled(Xl, flat, yl - al, scale=1.0 / n,
                                         reg=0.0, impl=impl, **tk)
            G, r = _psum_packet(Gl, rl, axis, fuse_packet)   # THE sync point
            A = G + lam * overlap_matrix(flat).astype(dtype)
            base = r - lam * w[flat]
            dws = block_forward_substitution(A, base, s, b)  # local, replicated
            w = w.at[flat].add(dws)                          # Eq. (9), replicated
            al = al + panel_apply(Xl, flat, dws, impl=impl, **tk)  # Eq. (10), local shard
            return (w, al), None

        (w, al), _ = jax.lax.scan(outer, (w, al), idx_rep, unroll=unroll)
        return w, al

    fn = compat.shard_map(body, mesh=mesh,
                          in_specs=(P(None, axis), P(axis), P(None)),
                          out_specs=(P(None), P(axis)))
    w, alpha = fn(X, y, idx)
    return w, alpha[:n]


def bcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                iters: int, key: jax.Array, *, axis: str = "shards",
                fuse_packet: bool = False, idx: jax.Array | None = None,
                impl: str | None = None,
                tiles: tuple[int, int] | None = None):
    """Classical distributed BCD (Theorem 1 schedule): per-iteration reductions.
    Implemented as CA with s=1; ``fuse_packet=False`` keeps the paper's separate
    Gram and residual reductions."""
    return ca_bcd_sharded(mesh, X, y, lam, b, 1, iters, key, axis=axis,
                          fuse_packet=fuse_packet, idx=idx, impl=impl,
                          tiles=tiles)


# --------------------------------------------------------------------------
# Dual: 1D-block-row
# --------------------------------------------------------------------------

def ca_bdcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                    s: int, iters: int, key: jax.Array, *,
                    axis: str = "shards", fuse_packet: bool = True,
                    idx: jax.Array | None = None, unroll: int = 1,
                    impl: str | None = None,
                    tiles: tuple[int, int] | None = None):
    """CA-BDCD with X (d, n) sharded over rows.  Returns (w sharded over d,
    alpha replicated).  ``impl`` selects the Gram-packet backend."""
    d, n = X.shape
    if iters % s:
        raise ValueError(f"iters={iters} must be a multiple of s={s}")
    if idx is None:
        idx = sample_blocks(key, n, b, iters)
    idx = idx.reshape(iters // s, s, b)
    sb = s * b
    dtype = X.dtype
    tk = _tile_kw(tiles)
    n_shards = math.prod(mesh.shape[a] for a in _axes(axis))
    X = _pad_to(X, n_shards, axis=0)

    def body(Xl, y_rep, idx_rep):
        wl = _pvary(jnp.zeros(Xl.shape[:1], dtype), axis)  # local shard of w
        alpha = jnp.zeros((n,), dtype)             # replicated dual iterate
        XlT = Xl.T         # once per shard, outside the scan: the sampled
        # columns of Xl become rows, so the packet and the deferred update
        # stay panel-free inside the hot loop.

        def outer(carry, idx_k):
            wl, alpha = carry
            flat = idx_k.reshape(sb)
            # One panel-free packet: Gl = Yl^T Yl / (lam n^2) plus the
            # *unscaled* local contribution to Y^T w (scale_r=1), with
            # Yl^T = XlT[flat, :] gathered inside the kernel; reg added after
            # the psum.
            Gl, ul = gram_packet_sampled(XlT, flat, wl,
                                         scale=1.0 / (lam * n * n),
                                         scale_r=1.0, reg=0.0, impl=impl,
                                         **tk)
            G, u = _psum_packet(Gl, ul, axis, fuse_packet)   # THE sync point
            A = G + overlap_matrix(flat).astype(dtype) / n
            base = (u - alpha[flat] - y_rep[flat]) / n
            das = block_forward_substitution(A, base, s, b)
            alpha = alpha.at[flat].add(das)                  # Eq. (20), replicated
            # Eq. (19), local shard: wl -= Yl das / (lam n).
            wl = wl - panel_apply(XlT, flat, das, impl=impl, **tk) / (lam * n)
            return (wl, alpha), None

        (wl, alpha), _ = jax.lax.scan(outer, (wl, alpha), idx_rep, unroll=unroll)
        return wl, alpha

    fn = compat.shard_map(body, mesh=mesh,
                          in_specs=(P(axis, None), P(None), P(None)),
                          out_specs=(P(axis), P(None)))
    wl, alpha = fn(X, y, idx)
    return wl[:d], alpha


def bdcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                 iters: int, key: jax.Array, *, axis: str = "shards",
                 fuse_packet: bool = False, idx: jax.Array | None = None,
                 impl: str | None = None,
                 tiles: tuple[int, int] | None = None):
    """Classical distributed BDCD (Theorem 2 schedule)."""
    return ca_bdcd_sharded(mesh, X, y, lam, b, 1, iters, key, axis=axis,
                           fuse_packet=fuse_packet, idx=idx, impl=impl,
                           tiles=tiles)


# --------------------------------------------------------------------------
# Lowering helpers (used by tests, benchmarks, and the dry-run)
# --------------------------------------------------------------------------

def lower_solver(solver, mesh: Mesh, d: int, n: int, lam: float, b: int, s: int,
                 iters: int, *, axis: str = "shards", fuse_packet: bool = True,
                 dtype=jnp.float32, col_sharded: bool = True, unroll: int = 1,
                 impl: str | None = None,
                 tiles: tuple[int, int] | None = None):
    """Lower+compile a solver on abstract operands; returns the Compiled object
    (for HLO collective counting and roofline terms).  ``impl`` and ``tiles``
    (explicit kernel (bm, bk), overriding the autotuned pick) are forwarded to
    the solver's Gram-packet dispatch."""
    from jax.sharding import NamedSharding
    xspec = P(None, axis) if col_sharded else P(axis, None)
    yspec = P(axis) if col_sharded else P(None)
    X = jax.ShapeDtypeStruct((d, n), dtype, sharding=NamedSharding(mesh, xspec))
    y_len = n
    y = jax.ShapeDtypeStruct((y_len,), dtype, sharding=NamedSharding(mesh, yspec))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def run(Xv, yv, keyv):
        return solver(mesh, Xv, yv, lam, b, s, iters,
                      jax.random.wrap_key_data(keyv), axis=axis,
                      fuse_packet=fuse_packet, unroll=unroll, impl=impl,
                      tiles=tiles)

    return jax.jit(run).lower(X, y, key).compile()

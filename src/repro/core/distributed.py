"""Distributed (CA-)BCD / (CA-)BDCD: the s-step engine's shard_map backend.

Since PR 3 the four entry points below are thin wrappers over
``repro.core.engine.s_step_solve_sharded`` -- the SAME outer-step body as the
single-device solvers, wrapped in shard_map with the formulation's layout and
one all-reduce inserted at the packet (``engine._packet_reduce``).  There is
no duplicated outer/inner loop pair here anymore; this module only carries
the public signatures, the mesh helper, and the lowering helper used by the
collective-count tests.

Layouts follow the paper's analysis (section 4):

* (CA-)BCD : 1D-block-column -- X's data-point axis (n) sharded, vectors in
  R^n sharded, vectors in R^d replicated.  The Gram of sampled *rows* then
  needs one psum over the column axis per Gram (Theorems 1/6).
* (CA-)BDCD: 1D-block-row -- X's feature axis (d) sharded, vectors in R^d
  sharded, vectors in R^n replicated (Theorems 2/7).

Communication structure (the paper's claim, verified by HLO count in tests):
every outer iteration has exactly ONE synchronization event on the wire.
``fuse_packet=True`` (default) concatenates the sb x sb Gram and the
sb-vector residual into one sb x (sb+1) operand; ``fuse_packet=False`` keeps
the paper's two logical reductions as separate operands but packs them into
one explicit variadic psum (``engine.psum_variadic``), so the collective
*count* is schedule-independent -- 1 all-reduce per outer iteration either
way, which tests/dist_checks.py pins down.  (Before PR 3 the unfused baseline
emitted 2 all-reduces/iteration on XLA builds without the all-reduce
combiner; the ROADMAP open item this resolves.)

All devices compute identical block indices from the replicated key (the
paper's shared-seed trick), so the overlap terms and the inner block forward
substitution are local and replicated.  The local (G, r) contributions are
built panel-free by ``gram_packet_sampled`` on each shard through the
formulation's PacketOperand -- row-major for the primal's column shards,
column-major for the dual's row shards, so the dual's ``Xl`` is never
transposed or copied inside the shard_map body (see the data-flow notes in
``repro.core.bcd`` / ``repro.core.bdcd``); mesh construction and shard_map
go through ``repro.compat`` so the same code runs on JAX 0.4.37 and newer
API generations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from .engine import (FORMULATIONS, SolverPlan, TenantBatch, get_solver,
                     register_solver, s_step_solve_batched,
                     s_step_solve_batched_sharded, s_step_solve_sharded)


def make_solver_mesh(n_devices: int | None = None, name: str = "shards") -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    return compat.make_mesh((n,), (name,))


# --------------------------------------------------------------------------
# Primal: 1D-block-column
# --------------------------------------------------------------------------

def ca_bcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                   s: int, iters: int, key: jax.Array, *,
                   axis: str = "shards", fuse_packet: bool = True,
                   idx: jax.Array | None = None, unroll: int = 1,
                   impl: str | None = None,
                   tiles: tuple[int, int] | None = None, guard: bool = False,
                   fault=None, x0: jax.Array | None = None, step0: int = 0):
    """CA-BCD with X (d, n) sharded over columns.  s=1 gives the classical
    schedule (one Gram reduction per iteration).  Returns (w replicated,
    alpha sharded over n) -- plus the replicated guard metrics dict when
    ``guard`` is set.  ``impl`` selects the Gram-packet backend for the
    local (G, r) contributions (see ``repro.kernels.gram``); ``tiles`` pins
    the kernel's (bm, bk) instead of the autotuned pick.  ``guard`` fuses
    the health word into the packet all-reduce (still ONE collective per
    outer iteration); ``fault`` is the test-only injection hook; ``x0`` /
    ``step0`` warm-start a segmented (checkpoint-resumed) solve."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault)
    return s_step_solve_sharded("primal", plan, mesh, X, y, lam, iters, key,
                                axis=axis, idx=idx, x0=x0, step0=step0)


def bcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                iters: int, key: jax.Array, *, axis: str = "shards",
                fuse_packet: bool = False, idx: jax.Array | None = None,
                impl: str | None = None,
                tiles: tuple[int, int] | None = None):
    """Classical distributed BCD (Theorem 1 schedule): per-iteration
    reductions, i.e. the engine at s=1; ``fuse_packet=False`` keeps the
    paper's separate Gram and residual operands (variadic packet)."""
    return ca_bcd_sharded(mesh, X, y, lam, b, 1, iters, key, axis=axis,
                          fuse_packet=fuse_packet, idx=idx, impl=impl,
                          tiles=tiles)


# --------------------------------------------------------------------------
# Dual: 1D-block-row
# --------------------------------------------------------------------------

def ca_bdcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                    s: int, iters: int, key: jax.Array, *,
                    axis: str = "shards", fuse_packet: bool = True,
                    idx: jax.Array | None = None, unroll: int = 1,
                    impl: str | None = None,
                    tiles: tuple[int, int] | None = None, guard: bool = False,
                    fault=None, x0: jax.Array | None = None, step0: int = 0):
    """CA-BDCD with X (d, n) sharded over rows.  Returns (w sharded over d,
    alpha replicated) -- plus the replicated guard metrics dict when
    ``guard`` is set.  ``impl`` selects the Gram-packet backend; ``guard`` /
    ``fault`` / ``x0`` / ``step0`` as in :func:`ca_bcd_sharded` (``x0`` is
    the replicated alpha iterate here)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault)
    return s_step_solve_sharded("dual", plan, mesh, X, y, lam, iters, key,
                                axis=axis, idx=idx, x0=x0, step0=step0)


def bdcd_sharded(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float, b: int,
                 iters: int, key: jax.Array, *, axis: str = "shards",
                 fuse_packet: bool = False, idx: jax.Array | None = None,
                 impl: str | None = None,
                 tiles: tuple[int, int] | None = None):
    """Classical distributed BDCD (Theorem 2 schedule)."""
    return ca_bdcd_sharded(mesh, X, y, lam, b, 1, iters, key, axis=axis,
                           fuse_packet=fuse_packet, idx=idx, impl=impl,
                           tiles=tiles)


# --------------------------------------------------------------------------
# Pipelined backend: the same solves on the ring wire (DESIGN.md section 9)
# --------------------------------------------------------------------------

def ca_bcd_pipelined(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float,
                     b: int, s: int, iters: int, key: jax.Array, *,
                     axis: str = "shards", fuse_packet: bool = True,
                     idx: jax.Array | None = None, unroll: int = 1,
                     impl: str | None = None,
                     tiles: tuple[int, int] | None = None,
                     guard: bool = False, fault=None,
                     x0: jax.Array | None = None, step0: int = 0):
    """:func:`ca_bcd_sharded` on the pipelined wire: the packet reduction is
    decomposed into a two-phase ring of collective-permute hops and the next
    outer step's Gram contraction is software-pipelined between the phases
    (``SolverPlan.wire="ring"``; the engine's ``_drive_pipelined``).  Same
    layout, same signature, iterates equal to the psum backend to f64 ~1e-12
    (ring vs tree summation order -- documented in tests/dist_checks.py)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault, wire="ring")
    return s_step_solve_sharded("primal", plan, mesh, X, y, lam, iters, key,
                                axis=axis, idx=idx, x0=x0, step0=step0)


def ca_bdcd_pipelined(mesh: Mesh, X: jax.Array, y: jax.Array, lam: float,
                      b: int, s: int, iters: int, key: jax.Array, *,
                      axis: str = "shards", fuse_packet: bool = True,
                      idx: jax.Array | None = None, unroll: int = 1,
                      impl: str | None = None,
                      tiles: tuple[int, int] | None = None,
                      guard: bool = False, fault=None,
                      x0: jax.Array | None = None, step0: int = 0):
    """:func:`ca_bdcd_sharded` on the pipelined ring wire (see
    :func:`ca_bcd_pipelined`)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault, wire="ring")
    return s_step_solve_sharded("dual", plan, mesh, X, y, lam, iters, key,
                                axis=axis, idx=idx, x0=x0, step0=step0)


# The CA wrappers (s=1 = classical) are the canonical registry entries.
register_solver("primal", "sharded", ca_bcd_sharded)
register_solver("dual", "sharded", ca_bdcd_sharded)
register_solver("primal", "pipelined", ca_bcd_pipelined)
register_solver("dual", "pipelined", ca_bdcd_pipelined)


# --------------------------------------------------------------------------
# Lowering helpers (used by tests, benchmarks, and the dry-run)
# --------------------------------------------------------------------------

_CALLABLE_FORMULATION = {}  # populated below; callable wrapper -> registry key


def _resolve_formulation(solver):
    if isinstance(solver, str):
        return solver
    try:
        return _CALLABLE_FORMULATION[solver]
    except KeyError:
        raise ValueError(
            f"lower_solver expects a formulation name {tuple(FORMULATIONS)} "
            f"or one of the sharded solver entry points, got {solver!r}"
        ) from None


_CALLABLE_FORMULATION.update({
    ca_bcd_sharded: "primal", bcd_sharded: "primal",
    ca_bdcd_sharded: "dual", bdcd_sharded: "dual",
    ca_bcd_pipelined: "primal", ca_bdcd_pipelined: "dual",
})

_CALLABLE_BACKEND = {ca_bcd_pipelined: "pipelined",
                     ca_bdcd_pipelined: "pipelined"}


def lower_solver(solver, mesh: Mesh, d: int, n: int, lam: float, b: int, s: int,
                 iters: int, *, axis: str = "shards", fuse_packet: bool = True,
                 dtype=jnp.float32, col_sharded: bool | None = None,
                 unroll: int = 1, impl: str | None = None,
                 tiles: tuple[int, int] | None = None,
                 backend: str = "sharded", **solver_kw):
    """Lower+compile a solver on abstract operands; returns the Compiled object
    (for HLO collective counting and roofline terms).  ``solver`` is a
    formulation name from the registry (``"primal"`` / ``"dual"`` /
    ``"proximal"`` / ``"accelerated"``) or one of the distributed solver
    entry points (back-compat; a pipelined entry point implies
    ``backend="pipelined"``).  ``backend`` picks the distributed registry
    column for a string ``solver`` -- ``"sharded"`` (psum wire) or
    ``"pipelined"`` (ring wire).  Input shardings are derived from the
    formulation's layout; ``col_sharded`` is retained for callers that pin it
    explicitly.  ``impl`` and ``tiles`` (explicit kernel (bm, bk), overriding
    the autotuned pick) are forwarded to the solver's Gram-packet dispatch;
    any extra ``solver_kw`` (e.g. the proximal formulation's ``lam1``) ride
    through to the solver entry."""
    from jax.sharding import NamedSharding
    formulation = _resolve_formulation(solver)
    if not isinstance(solver, str):
        backend = _CALLABLE_BACKEND.get(solver, backend)
    solve = get_solver(formulation, backend)
    if col_sharded is None:
        # The Formulation owns its layout: lower with the same input specs
        # its shard_map body expects, so the compiled collective schedule is
        # the solver's own (no resharding inserted by jit).
        xspec, yspec, _ = FORMULATIONS[formulation].dist_in_specs(axis)
    else:
        xspec = P(None, axis) if col_sharded else P(axis, None)
        yspec = P(axis) if col_sharded else P(None)
    X = jax.ShapeDtypeStruct((d, n), dtype, sharding=NamedSharding(mesh, xspec))
    y = jax.ShapeDtypeStruct((n,), dtype, sharding=NamedSharding(mesh, yspec))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def run(Xv, yv, keyv):
        return solve(mesh, Xv, yv, lam, b, s, iters,
                     jax.random.wrap_key_data(keyv), axis=axis,
                     fuse_packet=fuse_packet, unroll=unroll, impl=impl,
                     tiles=tiles, **solver_kw)

    return jax.jit(run).lower(X, y, key).compile()


def _batched_lowering_operands(formulation, tenants, d, n, dtype, coeff_names,
                               mesh=None, axis="shards"):
    """Abstract (X, ys, lams, coeffs, key) operands for a batched lowering:
    per-tenant targets lead with the tenant axis (replicated), everything
    else follows the formulation's single-solve layout."""
    from jax.sharding import NamedSharding
    form = FORMULATIONS[formulation] if isinstance(formulation, str) \
        else formulation
    if mesh is None:
        X = jax.ShapeDtypeStruct((d, n), dtype)
        ys = jax.ShapeDtypeStruct((tenants, n), dtype)
    else:
        xspec, yspec, _ = form.dist_in_specs(axis)
        X = jax.ShapeDtypeStruct((d, n), dtype,
                                 sharding=NamedSharding(mesh, xspec))
        ys = jax.ShapeDtypeStruct(
            (tenants, n), dtype,
            sharding=NamedSharding(mesh, P(*((None,) + tuple(yspec)))))
    lams = jax.ShapeDtypeStruct((tenants,), dtype)
    coeffs = {name: jax.ShapeDtypeStruct((tenants,), dtype)
              for name in coeff_names}
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return X, ys, lams, coeffs, key


def lower_solver_batched(formulation, mesh: Mesh | None, d: int, n: int,
                         tenants: int, b: int, s: int, iters: int, *,
                         axis: str = "shards", dtype=jnp.float32,
                         unroll: int = 1, impl: str | None = None,
                         tiles: tuple[int, int] | None = None,
                         coeff_names: tuple = (), wire: str = "psum"):
    """Lower+compile a BATCHED multi-tenant solve on abstract operands --
    sharded when ``mesh`` is given, local otherwise.  The contract engine
    lowers these at T in {1, 8, 64} to machine-check the shared-packet
    invariant: exactly H = ceil(iters/s) reductions independent of T, with
    the Gram part of the per-step payload not scaled by T.  ``coeff_names``
    become per-tenant ``TenantBatch.coeffs`` entries (e.g. the proximal
    ``lam1``); ``wire="ring"`` lowers the pipelined backend's decomposed
    reduction (sharded only)."""
    formulation = _resolve_formulation(formulation) \
        if not isinstance(formulation, str) else formulation
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles, unroll=unroll,
                      tenants=tenants, wire=wire)
    X, ys, lams, coeffs, key = _batched_lowering_operands(
        formulation, tenants, d, n, dtype, coeff_names, mesh=mesh, axis=axis)

    def run(Xv, ysv, lamsv, coeffsv, keyv):
        batch = TenantBatch(ys=ysv, lams=lamsv, coeffs=coeffsv)
        k = jax.random.wrap_key_data(keyv)
        if mesh is None:
            return s_step_solve_batched(formulation, plan, Xv, batch, iters, k)
        return s_step_solve_batched_sharded(formulation, plan, mesh, Xv,
                                            batch, iters, k, axis=axis)

    return jax.jit(run).lower(X, ys, lams, coeffs, key).compile()


def lower_solver_local(formulation: str, d: int, n: int, lam: float, b: int,
                       s: int, iters: int, *, dtype=jnp.float32,
                       impl: str | None = None,
                       tiles: tuple[int, int] | None = None, **solver_kw):
    """Lower+compile the LOCAL (single-device) registry solver on abstract
    operands.  The contract engine uses this to assert the local backend is
    collective-free and (for pallas impls) panel-free; mirrors
    :func:`lower_solver` but needs no mesh and no sharding derivation."""
    solve = get_solver(formulation, "local")
    X = jax.ShapeDtypeStruct((d, n), dtype)
    y = jax.ShapeDtypeStruct((n,), dtype)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def run(Xv, yv, keyv):
        return solve(Xv, yv, lam, b, s, iters,
                     jax.random.wrap_key_data(keyv), impl=impl, tiles=tiles,
                     **solver_kw)

    return jax.jit(run).lower(X, y, key).compile()

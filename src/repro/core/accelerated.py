"""Accelerated (momentum) CA-BCD -- the fourth Formulation.

Communication-efficient primal-dual work (Devarakonda et al.,
arXiv:1711.05305) shows the s-step packet can also carry acceleration
state: the deferred block updates the engine already applies are exactly
the increments a momentum recurrence needs, so the iteration count drops
with ZERO change to the wire.  :class:`MomentumWrapper` wraps the primal
ridge hooks with a per-coordinate velocity

    v[i] <- beta * v[i] + dw[i]        (the engine's ridge block step dw)
    w[i] <- w[i] + v[i],   alpha <- alpha + Y_i^T v[i]

kept in the scan carry next to ``(w, alpha)`` -- replicated like w in the
distributed layout, so the momentum term adds ZERO extra collectives: the
packet, its single reduction (psum or the pipelined ring wire), and the
health word are byte-identical to the primal's.  ``beta = 0`` IS the
classical primal update bit-for-bit (static branch, the proximal
``lam1 = 0`` idiom -- no momentum code in the lowering), which is how the
classical rate is recovered and how the equivalence tests pin the wrapper.
At ``s = 1`` the schedule is exactly classical heavy-ball BCD; at ``s > 1``
the velocity reshapes the deferred updates only (see
:func:`ca_accelerated_bcd` on the CoCoA-style semantics).

The per-block inner subproblems are untouched (same Gram packet, same
block forward substitution); only the APPLIED step is reshaped, which is
precisely the ``update`` hook's contract.  Like every formulation the
engine runs, ``s = 1`` is the classical momentum schedule and
``iters % s != 0`` runs a ragged tail.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engine import (RowMajorOperand, SolveResult, SolverContracts,
                     SolverPlan, _BoundPrimal, _pad_to, panel_apply,
                     register_formulation, register_solver, s_step_solve,
                     s_step_solve_sharded)


@dataclasses.dataclass(frozen=True)
class _BoundAccelerated(_BoundPrimal):
    """Primal hooks + the velocity carry.  The packet side (operand, scales,
    packet_vector, base, inner_sweep) is the primal ridge's untouched --
    ``packet_vector``/``base`` already index the carry positionally, so the
    widened ``(w, alpha, v)`` carry flows through them unchanged.  Only
    ``init_carry`` (adds v), ``update`` (applies the momentum step) and
    ``metrics`` (drops v) differ."""
    beta: float = 0.0

    def init_carry(self, axes=None):
        w, alpha = _BoundPrimal.init_carry(self, axes=axes)
        # v matches w's layout exactly (replicated in the distributed mode);
        # a warm restart re-enters with zero velocity -- momentum state is
        # deliberately NOT checkpoint state (DESIGN.md section 7).
        return w, alpha, jnp.zeros_like(w)

    def update(self, carry, idx, dx, pp):
        w, alpha, v = carry
        if isinstance(self.beta, (int, float)) and not self.beta:
            # Static branch: beta=0 lowers to the primal update itself,
            # which is what makes the bit-for-bit classical equivalence
            # hold (beta*v + dx == dx only in exact arithmetic once v has
            # rounded state; here v stays exactly zero and the op sequence
            # is the primal's).
            w, alpha = _BoundPrimal.update(self, (w, alpha), idx, dx, pp)
            return w, alpha, v
        vi = self.beta * v[idx] + dx
        v = v.at[idx].set(vi)
        w = w.at[idx].add(vi)
        alpha = alpha + panel_apply(self.operand, idx, vi, plan=pp)
        return w, alpha, v

    def metrics(self, carry):
        return _BoundPrimal.metrics(self, (carry[0], carry[1]))


@dataclasses.dataclass(frozen=True)
class MomentumWrapper:
    """Accelerated CA-BCD: samples features like the primal, 1D-block-column
    layout.  ``beta`` is formulation state (the proximal ``lam1`` pattern) so
    the engine signatures stay untouched: the wrappers below build
    ``MomentumWrapper(beta=...)`` per call, and the registry's default
    instance is what layout resolution sees."""
    beta: float = 0.9
    name: ClassVar[str] = "accelerated"
    operand_layout: ClassVar[str] = "rows"

    def __post_init__(self):
        # Fail fast on a non-contractive momentum weight; only concrete
        # numbers are checkable (a tracer passes through).
        if isinstance(self.beta, (int, float)) and not 0.0 <= self.beta < 1.0:
            raise ValueError(f"beta={self.beta!r} must be in [0, 1)")

    def contracts(self):
        # The velocity is carry state on the replicated iterate: same wire
        # as the primal ridge on BOTH schedules (one packet all-reduce per
        # outer iteration, or the pipelined ring decomposition), health word
        # riding it, zero extra collectives.  ``lowering_kwargs`` makes the
        # analysis engine lower with beta > 0 so the momentum path (not the
        # beta=0 primal branch) is the one verified.  Not tenant-batched:
        # the batched engine's carry is pinned to (ws, alphas) pairs.
        return SolverContracts(lowering_kwargs=(("beta", 0.5),),
                               health_in_packet=True, tenant_batched=False)

    def sample_dim(self, d, n):
        return d

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        d, n = X.shape
        return _BoundAccelerated(operand=RowMajorOperand(X), y=y, lam=lam,
                                 n=n, d=d, w0=x0, w_ref=w_ref, beta=self.beta)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 1), _pad_to(y, n_shards, 0)

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        return _BoundAccelerated(operand=RowMajorOperand(Xl), y=yl, lam=lam,
                                 n=n, d=d, w0=x0, beta=self.beta)

    def dist_in_specs(self, axis):
        return P(None, axis), P(axis), P(None)

    def dist_out_specs(self, axis):
        # (w, alpha, v): the velocity is replicated like w.
        return P(None), P(axis), P(None)

    def dist_finalize(self, w, alpha, d, n):
        return w, alpha[:n]


def accelerated_bcd(X: jax.Array, y: jax.Array, lam: float, b: int,
                    iters: int, key: jax.Array, *, beta: float = 0.9,
                    w0: jax.Array | None = None, idx: jax.Array | None = None,
                    w_ref: jax.Array | None = None, impl: str | None = None,
                    tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical momentum BCD: the s-step engine at s=1.  ``beta=0`` IS
    :func:`~repro.core.bcd`."""
    plan = SolverPlan(b=b, s=1, impl=impl, tiles=tiles)
    return s_step_solve(MomentumWrapper(beta=beta), plan, X, y, lam, iters,
                        key, x0=w0, idx=idx, w_ref=w_ref)


def ca_accelerated_bcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int,
                       iters: int, key: jax.Array, *, beta: float = 0.9,
                       w0: jax.Array | None = None,
                       idx: jax.Array | None = None,
                       w_ref: jax.Array | None = None,
                       track_cond: bool = False, impl: str | None = None,
                       tiles: tuple[int, int] | None = None,
                       guard: bool = False, fault=None,
                       step0: int = 0) -> SolveResult:
    """CA momentum BCD (arXiv:1711.05305): one sb x sb Gram packet per outer
    iteration, then ``s`` local momentum-applied block solves.

    At ``s=1`` this IS classical heavy-ball BCD (one block per packet, the
    velocity applied immediately).  For ``s>1`` the momentum rides the
    DEFERRED block updates: the inner sweep's forward-substitution
    corrections assume the plain ``dx`` steps (that is what the packet
    proves), and the velocity reshapes only the APPLIED update -- the CoCoA
    -style local-subproblem flexibility (arXiv:1409.1458), not an exact
    reordering of the classical momentum schedule.  Fixed point and wire
    schedule are unchanged, and ``beta=0`` recovers plain CA-BCD bit-for-bit
    at every ``s``.  ``iters % s != 0`` runs a ragged final outer
    iteration."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles, track_cond=track_cond,
                      guard=guard, fault=fault)
    return s_step_solve(MomentumWrapper(beta=beta), plan, X, y, lam, iters,
                        key, x0=w0, idx=idx, w_ref=w_ref, step0=step0)


def ca_accelerated_bcd_sharded(mesh, X: jax.Array, y: jax.Array, lam: float,
                               b: int, s: int, iters: int, key: jax.Array, *,
                               beta: float = 0.9, axis: str = "shards",
                               fuse_packet: bool = True,
                               idx: jax.Array | None = None, unroll: int = 1,
                               impl: str | None = None,
                               tiles: tuple[int, int] | None = None,
                               guard: bool = False, fault=None,
                               x0: jax.Array | None = None, step0: int = 0):
    """Distributed CA momentum BCD: the primal's 1D-block-column layout, ONE
    packet all-reduce per outer iteration -- the velocity is replicated
    carry state, so momentum adds zero communication.  Returns (w
    replicated, alpha sharded over n) -- plus the replicated guard metrics
    dict when ``guard`` is set."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault)
    return s_step_solve_sharded(MomentumWrapper(beta=beta), plan, mesh, X, y,
                                lam, iters, key, axis=axis, idx=idx, x0=x0,
                                step0=step0)


def ca_accelerated_bcd_pipelined(mesh, X: jax.Array, y: jax.Array, lam: float,
                                 b: int, s: int, iters: int, key: jax.Array,
                                 *, beta: float = 0.9, axis: str = "shards",
                                 fuse_packet: bool = True,
                                 idx: jax.Array | None = None,
                                 unroll: int = 1, impl: str | None = None,
                                 tiles: tuple[int, int] | None = None,
                                 guard: bool = False, fault=None,
                                 x0: jax.Array | None = None, step0: int = 0):
    """:func:`ca_accelerated_bcd_sharded` on the pipelined ring wire
    (DESIGN.md section 9): same layout, same momentum math, the packet
    reduction decomposed into overlappable collective-permute hops."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault, wire="ring")
    return s_step_solve_sharded(MomentumWrapper(beta=beta), plan, mesh, X, y,
                                lam, iters, key, axis=axis, idx=idx, x0=x0,
                                step0=step0)


register_formulation(MomentumWrapper())
register_solver("accelerated", "local", ca_accelerated_bcd)
register_solver("accelerated", "sharded", ca_accelerated_bcd_sharded)
register_solver("accelerated", "pipelined", ca_accelerated_bcd_pipelined)

# Let lower_solver resolve the wrappers itself, like the ridge entries.
from .distributed import _CALLABLE_BACKEND, _CALLABLE_FORMULATION  # noqa: E402

_CALLABLE_FORMULATION[ca_accelerated_bcd_sharded] = "accelerated"
_CALLABLE_FORMULATION[ca_accelerated_bcd_pipelined] = "accelerated"
_CALLABLE_BACKEND[ca_accelerated_bcd_pipelined] = "pipelined"

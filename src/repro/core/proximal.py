"""CA proximal (elastic-net) block coordinate descent -- the third Formulation.

Solves the elastic-net regularized least-squares problem

    min_w  1/(2n) ||X^T w - y||^2 + lam/2 ||w||^2 + lam1 ||w||_1,   X in R^{d x n}

with the s-step engine (``repro.core.engine``), per the proximal/sparse
communication-avoiding methods of Devarakonda et al. (arXiv:1712.06047):
the SAME sb x sb Gram-packet structure as CA-BCD -- one communication point
per outer iteration -- with a soft-threshold applied inside the inner
recurrence (``subproblem.block_forward_substitution_prox``).

Block update (s=1, the classical schedule): sample b features ``i``, form

    Gamma = Y Y^T / n + lam I,         Y = X[i, :]
    r     = Y (y - alpha) / n - lam w[i]          (minus the smooth gradient)
    v     = Gamma^{-1} r                          (ridge candidate, Cholesky)
    w[i] <- S(w[i] + v, lam1 / diag(Gamma))       (soft-threshold)

For b = 1 this is the exact elastic-net coordinate minimizer (the textbook
shooting update); for b > 1 it is the standard prox-Newton-style composite
step -- the smooth block minimizer followed by a diagonally-scaled
soft-threshold.  The CA identity is unaffected by the nonsmooth term: the
s-step recurrence only linearizes the *smooth* part, which is exact for any
applied update, so CA-PBCD(s) reproduces the classical proximal iterates for
every grouping of the index stream (tested, ragged tail included), and
``lam1 = 0`` IS the ridge sweep bit-for-bit (static branch, no prox code in
the lowering).

This is the first formulation added *through* the registry rather than
refactored into it; the engine hook it exercised into existence is
``BoundFormulation.inner_sweep`` (the subproblem solver used to be hardwired
to the ridge sweep in ``_outer_step``).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engine import (RowMajorOperand, SolveResult, SolverContracts,
                     SolverPlan, _BoundPrimal, _fit_residual,
                     _objective_from_alpha, _pad_to, _sol_err,
                     register_formulation, register_solver, s_step_solve,
                     s_step_solve_sharded)
from .sampling import overlap_matrix
from .subproblem import (block_forward_substitution,
                         block_forward_substitution_prox, soft_threshold)


@dataclasses.dataclass(frozen=True)
class _BoundProximal(_BoundPrimal):
    """Primal hooks + the prox-aware sweep and elastic-net metrics.

    Everything the packet needs (operand, scale, reg, packet_vector, base,
    update) is the primal ridge's -- the l1 term has no gradient to ride the
    residual, it only reshapes each block's applied step -- so this bound
    inherits ``_BoundPrimal`` and overrides exactly the two hooks the
    nonsmooth term touches.  Layout-neutral like its parent: on a column
    shard (w replicated) every device computes identical thresholds and
    applied updates from the replicated post-reduce packet.
    """
    lam1: float = 0.0

    def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
        if isinstance(self.lam1, (int, float)) and not self.lam1:
            # Static branch: lam1=0 lowers to the ridge sweep itself, which
            # is what makes the bit-for-bit equivalence with the primal
            # formulation hold (S(w + v, 0) - w == v only in exact
            # arithmetic, not in floats).  The isinstance guard keeps a
            # TRACED lam1 -- the batched engine's per-tenant coefficient
            # under vmap -- on the prox path (a tracer cannot pick a python
            # branch, and the per-tenant ridge case is S(., 0), exact only
            # up to float identity, which the batched equivalence tests pin
            # by passing lam1 > 0 everywhere).
            return block_forward_substitution(A, base, s_k, b)
        # diag(A) = ||x_i||^2 / n + lam in every mode: the engine applies
        # reg post-contraction everywhere -- reg*I locally at s_k=1 and
        # reg * O (O's diagonal is 1) otherwise.
        tau = self.lam1 / jnp.diagonal(A)
        if overlap is None:     # engine skips O at s_k == 1 (no cross terms)
            overlap = overlap_matrix(flat).astype(A.dtype)
        return block_forward_substitution_prox(
            A, base, s_k, b, w0=carry[0][flat], tau=tau, overlap=overlap)

    def metrics(self, carry):
        w, alpha = carry
        m = {"objective": _objective_from_alpha(alpha, w, self.y, self.lam)
             + self.lam1 * jnp.sum(jnp.abs(w)),
             "nnz": jnp.sum(w != 0).astype(w.dtype),
             "residual": _fit_residual(alpha, self.y)}
        if self.w_ref is not None:
            m["sol_err"] = _sol_err(w, self.w_ref)
        return m


@dataclasses.dataclass(frozen=True)
class ProximalElasticNet:
    """CA-PBCD: samples features like the primal, 1D-block-column layout.

    ``lam1`` is formulation state (not solver-plan state) so the engine's
    ``(X, y, lam, ...)`` signatures stay untouched: the wrappers below build
    ``ProximalElasticNet(lam1=...)`` per call, and the registry's default
    instance (lam1=0) is the ridge-equivalent used for layout resolution.
    """
    lam1: float = 0.0
    name: ClassVar[str] = "proximal"
    operand_layout: ClassVar[str] = "rows"

    def __post_init__(self):
        # Same fail-fast contract as the kernel knobs: a negative lam1 turns
        # the soft-threshold into sign(u) * (|u| + |lam1|/diag) -- an
        # inflation step that silently diverges instead of sparsifying.
        # Only concrete numbers are checkable; an array/tracer lam1 (the
        # batched engine's per-tenant coefficient) passes through.
        if isinstance(self.lam1, (int, float)) and not self.lam1 >= 0:
            raise ValueError(f"lam1={self.lam1!r} must be >= 0")

    def contracts(self):
        # The soft-threshold runs on the replicated post-reduce packet, so
        # the nonsmooth term adds ZERO communication: same contract as the
        # primal ridge.  ``lowering_kwargs`` makes the analysis engine lower
        # with lam1 > 0 so the prox code path (not the lam1=0 ridge branch)
        # is the one verified.  ``health_in_packet``: the guard word rides
        # the same psum (verified with guard=True lowerings).
        # ``tenant_batched``: lam1 rides TenantBatch.coeffs as a per-tenant
        # bound field; the packet scales are the primal's (static), so the
        # batched engine shares the fully-scaled Gram across tenants.
        return SolverContracts(lowering_kwargs=(("lam1", 1e-3),),
                               health_in_packet=True, tenant_batched=True)

    def sample_dim(self, d, n):
        return d

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        d, n = X.shape
        return _BoundProximal(operand=RowMajorOperand(X), y=y, lam=lam, n=n,
                              d=d, w0=x0, w_ref=w_ref, lam1=self.lam1)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 1), _pad_to(y, n_shards, 0)

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        return _BoundProximal(operand=RowMajorOperand(Xl), y=yl, lam=lam,
                              n=n, d=d, w0=x0, lam1=self.lam1)

    def dist_in_specs(self, axis):
        return P(None, axis), P(axis), P(None)

    def dist_out_specs(self, axis):
        return P(None), P(axis)

    def dist_finalize(self, w, alpha, d, n):
        return w, alpha[:n]


def elastic_net_objective(X: jax.Array, w: jax.Array, y: jax.Array,
                          lam: float, lam1: float) -> jax.Array:
    """f(w) = 1/(2n) ||X^T w - y||^2 + lam/2 ||w||^2 + lam1 ||w||_1."""
    n = X.shape[1]
    r = X.T @ w - y
    return (0.5 / n * (r @ r) + 0.5 * lam * (w @ w)
            + lam1 * jnp.sum(jnp.abs(w)))


def proximal_bcd_reference(X: jax.Array, y: jax.Array, lam: float, lam1: float,
                           b: int, iters: int, idx) -> tuple[jax.Array, jax.Array]:
    """Hand-rolled classical proximal BCD (s=1): materialized panel, explicit
    dense solve, explicit threshold.  The independent oracle the engine's
    s=1 and s>1 iterates are tested against -- deliberately shares no code
    with the engine path."""
    d, n = X.shape
    w = jnp.zeros((d,), X.dtype)
    alpha = jnp.zeros((n,), X.dtype)
    for h in range(iters):
        i = idx[h]
        Y = X[i, :]
        Gamma = Y @ Y.T / n + lam * jnp.eye(b, dtype=X.dtype)
        r = Y @ (y - alpha) / n - lam * w[i]
        v = jnp.linalg.solve(Gamma, r)
        wi = soft_threshold(w[i] + v, lam1 / jnp.diag(Gamma))
        dw = wi - w[i]
        w = w.at[i].add(dw)
        alpha = alpha + Y.T @ dw
    return w, alpha


def proximal_bcd(X: jax.Array, y: jax.Array, lam: float, b: int, iters: int,
                 key: jax.Array, *, lam1: float = 0.0,
                 w0: jax.Array | None = None, idx: jax.Array | None = None,
                 w_ref: jax.Array | None = None, impl: str | None = None,
                 tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical proximal BCD: the s-step engine at s=1.  ``lam`` is the l2
    (ridge) weight, ``lam1`` the l1 weight; ``lam1=0`` IS :func:`~repro.core.bcd`."""
    plan = SolverPlan(b=b, s=1, impl=impl, tiles=tiles)
    return s_step_solve(ProximalElasticNet(lam1=lam1), plan, X, y, lam, iters,
                        key, x0=w0, idx=idx, w_ref=w_ref)


def ca_proximal_bcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int,
                    iters: int, key: jax.Array, *, lam1: float = 0.0,
                    w0: jax.Array | None = None, idx: jax.Array | None = None,
                    w_ref: jax.Array | None = None, track_cond: bool = False,
                    impl: str | None = None,
                    tiles: tuple[int, int] | None = None, guard: bool = False,
                    fault=None, step0: int = 0) -> SolveResult:
    """CA proximal BCD (arXiv:1712.06047): one sb x sb Gram packet per outer
    iteration, then ``s`` local prox-thresholded block solves.  Same index
    stream as :func:`proximal_bcd` => identical iterates in exact arithmetic;
    ``iters % s != 0`` runs a ragged final outer iteration.
    ``guard``/``fault``/``step0``: health guard, test-only injection hook,
    and segmented-solve step offset (DESIGN.md section 7)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles, track_cond=track_cond,
                      guard=guard, fault=fault)
    return s_step_solve(ProximalElasticNet(lam1=lam1), plan, X, y, lam, iters,
                        key, x0=w0, idx=idx, w_ref=w_ref, step0=step0)


def ca_proximal_bcd_sharded(mesh, X: jax.Array, y: jax.Array, lam: float,
                            b: int, s: int, iters: int, key: jax.Array, *,
                            lam1: float = 0.0, axis: str = "shards",
                            fuse_packet: bool = True,
                            idx: jax.Array | None = None, unroll: int = 1,
                            impl: str | None = None,
                            tiles: tuple[int, int] | None = None,
                            guard: bool = False, fault=None,
                            x0: jax.Array | None = None, step0: int = 0):
    """Distributed CA proximal BCD: X sharded over columns (the primal's
    1D-block-column layout), ONE packet all-reduce per outer iteration --
    the soft-threshold runs on the replicated post-reduce packet, so the
    nonsmooth term adds zero communication.  Returns (w replicated, alpha
    sharded over n) -- plus the replicated guard metrics dict when ``guard``
    is set.  ``guard``/``fault``/``x0``/``step0`` as in
    :func:`repro.core.distributed.ca_bcd_sharded`."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault)
    return s_step_solve_sharded(ProximalElasticNet(lam1=lam1), plan, mesh, X,
                                y, lam, iters, key, axis=axis, idx=idx, x0=x0,
                                step0=step0)


def ca_proximal_bcd_pipelined(mesh, X: jax.Array, y: jax.Array, lam: float,
                              b: int, s: int, iters: int, key: jax.Array, *,
                              lam1: float = 0.0, axis: str = "shards",
                              fuse_packet: bool = True,
                              idx: jax.Array | None = None, unroll: int = 1,
                              impl: str | None = None,
                              tiles: tuple[int, int] | None = None,
                              guard: bool = False, fault=None,
                              x0: jax.Array | None = None, step0: int = 0):
    """:func:`ca_proximal_bcd_sharded` on the pipelined ring wire (DESIGN.md
    section 9): same layout and threshold math, the packet reduction
    decomposed into overlappable collective-permute hops.  Matches the psum
    wire to f64 ~1e-12 (reduction order differs)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                      fuse_packet=fuse_packet, unroll=unroll, guard=guard,
                      fault=fault, wire="ring")
    return s_step_solve_sharded(ProximalElasticNet(lam1=lam1), plan, mesh, X,
                                y, lam, iters, key, axis=axis, idx=idx, x0=x0,
                                step0=step0)


register_formulation(ProximalElasticNet())
register_solver("proximal", "local", ca_proximal_bcd)
register_solver("proximal", "sharded", ca_proximal_bcd_sharded)
register_solver("proximal", "pipelined", ca_proximal_bcd_pipelined)

# Let lower_solver resolve the wrappers itself, like the ridge entries.
from .distributed import _CALLABLE_BACKEND, _CALLABLE_FORMULATION  # noqa: E402

_CALLABLE_FORMULATION[ca_proximal_bcd_sharded] = "proximal"
_CALLABLE_FORMULATION[ca_proximal_bcd_pipelined] = "proximal"
_CALLABLE_BACKEND[ca_proximal_bcd_pipelined] = "pipelined"

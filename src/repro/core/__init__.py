"""repro.core -- the paper's contribution: communication-avoiding primal and
dual block coordinate descent (CA-BCD / CA-BDCD) for regularized least squares,
plus the baselines it is compared against (CG, TSQR) and the alpha-beta-gamma
cost model used for the modeled scaling experiments."""
from .engine import (FORMULATIONS, BatchedSolveResult, DualRidge, Formulation,
                     PrimalRidge, SolveResult, SolverContracts, SolverPlan,
                     TenantBatch, get_solver, register_formulation,
                     register_solver, registered_solvers, s_step_solve,
                     batched_residuals,
                     s_step_solve_batched, s_step_solve_batched_sharded,
                     s_step_solve_sharded)
from .bcd import bcd, ca_bcd, objective
from .bdcd import bdcd, ca_bdcd
from .proximal import (ProximalElasticNet, ca_proximal_bcd,
                       ca_proximal_bcd_pipelined, ca_proximal_bcd_sharded,
                       elastic_net_objective, proximal_bcd,
                       proximal_bcd_reference)
from .accelerated import (MomentumWrapper, accelerated_bcd,
                          ca_accelerated_bcd, ca_accelerated_bcd_pipelined,
                          ca_accelerated_bcd_sharded)
from .direct import ridge_exact
from .distributed import (bcd_sharded, bdcd_sharded, ca_bcd_pipelined,
                          ca_bcd_sharded, ca_bdcd_pipelined, ca_bdcd_sharded,
                          lower_solver, lower_solver_batched,
                          make_solver_mesh)
from .hlo_analysis import (CollectiveSummary, collective_summary,
                           count_in_compiled, parse_collectives)
from repro.kernels.gram import (PacketPlan, gram, gram_packet,
                                gram_packet_sampled, normal_matvec,
                                panel_apply, panel_matvec)
from .krylov import cg_ridge, cg_ridge_history
from .sampling import overlap_matrix, sample_blocks, sample_blocks_balanced
from .subproblem import (block_forward_substitution,
                         block_forward_substitution_prox, soft_threshold,
                         solve_spd)
from .tsqr import cholqr_r, tsqr, tsqr_ridge
from . import cost_model

__all__ = [
    "SolveResult", "bcd", "ca_bcd", "bdcd", "ca_bdcd", "objective",
    "ridge_exact", "cg_ridge", "cg_ridge_history", "tsqr", "tsqr_ridge",
    "cholqr_r",
    "bcd_sharded", "bdcd_sharded", "ca_bcd_sharded", "ca_bdcd_sharded",
    "ca_bcd_pipelined", "ca_bdcd_pipelined", "lower_solver",
    "make_solver_mesh",
    "SolverPlan", "SolverContracts", "PacketPlan", "Formulation",
    "PrimalRidge", "DualRidge", "TenantBatch", "BatchedSolveResult",
    "ProximalElasticNet", "FORMULATIONS", "s_step_solve",
    "s_step_solve_sharded", "s_step_solve_batched", "batched_residuals",
    "s_step_solve_batched_sharded", "lower_solver_batched", "get_solver",
    "register_formulation", "register_solver", "registered_solvers",
    "proximal_bcd", "ca_proximal_bcd", "ca_proximal_bcd_sharded",
    "ca_proximal_bcd_pipelined",
    "proximal_bcd_reference", "elastic_net_objective",
    "MomentumWrapper", "accelerated_bcd", "ca_accelerated_bcd",
    "ca_accelerated_bcd_sharded", "ca_accelerated_bcd_pipelined",
    "gram", "gram_packet", "gram_packet_sampled", "panel_apply",
    "panel_matvec", "normal_matvec",
    "sample_blocks", "sample_blocks_balanced", "overlap_matrix",
    "block_forward_substitution", "block_forward_substitution_prox",
    "soft_threshold", "solve_spd",
    "CollectiveSummary", "collective_summary", "count_in_compiled",
    "parse_collectives", "cost_model",
]

"""Direct ridge solves used as ground truth (the paper uses CG with tol=1e-15;
a Cholesky direct solve is equivalent for our synthetic sizes and exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .subproblem import solve_spd


def ridge_exact(X: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    """w_opt = argmin lam/2||w||^2 + 1/(2n)||X^T w - y||^2.

    Uses the primal normal equations when d <= n, else the dual (kernel)
    identity w = X (X^T X/n + lam I)^{-1} y / n to keep the solve at
    min(d, n)^2 cost.
    """
    d, n = X.shape
    if d <= n:
        A = X @ X.T / n + lam * jnp.eye(d, dtype=X.dtype)
        return solve_spd(A, X @ y / n)
    A = X.T @ X / n + lam * jnp.eye(n, dtype=X.dtype)
    return X @ solve_spd(A, y) / n

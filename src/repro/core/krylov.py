"""Conjugate gradients on the regularized normal equations -- the paper's
Krylov baseline (Table 2, Figure 1) and its ground-truth generator
(``w_opt`` from "CG with tol 1e-15").

The matvec is computed as X (X^T v)/n + lam v, i.e. two panel products per
iteration and never a materialized d x d matrix, matching the O(kdn) flops of
Table 2.  One all-reduce per iteration in the distributed setting (the
matvec contraction) plus two dot-product reductions -- also O(k log P)
latency, which is the regime BCD/BDCD compete with in Figure 1c.

Both panel products route through the Gram-backend dispatch layer
(``repro.kernels.gram.normal_matvec``): jnp on the ref path, the streaming
``panel_apply`` / ``panel_matvec`` Pallas kernels when ``impl`` explicitly
selects the kernel backend.  ``impl=None`` keeps XLA's native dense matmul
on every backend (including TPU) so the CG baseline the solvers are compared
against is never silently handicapped by the row-DMA gather route.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.gram import normal_matvec


class CGResult(NamedTuple):
    w: jax.Array
    iters: jax.Array
    history: dict


def cg_ridge(X: jax.Array, y: jax.Array, lam: float, *, tol: float = 1e-15,
             max_iters: int = 1000, w_ref: jax.Array | None = None,
             impl: str | None = None) -> CGResult:
    d, n = X.shape
    rhs = X @ y / n

    def matvec(v):
        return normal_matvec(X, v, lam=lam, scale=1.0 / n, impl=impl)

    w0 = jnp.zeros((d,), X.dtype)
    r0 = rhs
    rs0 = r0 @ r0
    stop2 = (tol * jnp.linalg.norm(rhs)) ** 2

    def body(carry):
        w, r, p, rs, k = carry
        Ap = matvec(p)
        a = rs / (p @ Ap)
        w = w + a * p
        r = r - a * Ap
        rs_new = r @ r
        p = r + (rs_new / rs) * p
        return w, r, p, rs_new, k + 1

    def cond(carry):
        _, _, _, rs, k = carry
        return jnp.logical_and(rs > stop2, k < max_iters)

    w, r, p, rs, k = jax.lax.while_loop(
        cond, body, (w0, r0, r0, rs0, jnp.array(0, jnp.int32)))

    hist = {}
    if w_ref is not None:
        hist["sol_err"] = jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)
    return CGResult(w, k, hist)


def cg_ridge_history(X: jax.Array, y: jax.Array, lam: float, iters: int,
                     w_ref: jax.Array | None = None,
                     impl: str | None = None) -> CGResult:
    """Fixed-iteration CG that records per-iteration metrics (for Figure 1)."""
    d, n = X.shape
    rhs = X @ y / n

    def matvec(v):
        return normal_matvec(X, v, lam=lam, scale=1.0 / n, impl=impl)

    def step(carry, _):
        w, r, p, rs = carry
        Ap = matvec(p)
        a = rs / (p @ Ap)
        w = w + a * p
        r = r - a * Ap
        rs_new = r @ r
        p = r + (rs_new / rs) * p
        m = {"res_norm": jnp.sqrt(rs_new)}
        nloc = X.shape[1]
        obj_r = X.T @ w - y
        m["objective"] = 0.5 / nloc * (obj_r @ obj_r) + 0.5 * lam * (w @ w)
        if w_ref is not None:
            m["sol_err"] = jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)
        return (w, r, p, rs_new), m

    w0 = jnp.zeros((d,), X.dtype)
    (w, *_), hist = jax.lax.scan(step, (w0, rhs, rhs, rhs @ rhs), None, length=iters)
    return CGResult(w, jnp.array(iters, jnp.int32), hist)

"""Block subproblem solves shared by the classical and CA solvers.

The paper solves each ``b x b`` subproblem "implicitly by first constructing
the Gram matrix and computing its Cholesky factorization" (section 2.1).  We do
exactly that; ``solve_spd`` is the single choke point so tests can property-check
it and the CA inner loop (block forward substitution) reuses it unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl


def solve_spd(A: jax.Array, rhs: jax.Array) -> jax.Array:
    """Solve ``A x = rhs`` for symmetric positive definite ``A`` via Cholesky."""
    chol = jsl.cholesky(A, lower=True)
    return jsl.cho_solve((chol, True), rhs)


def block_forward_substitution(A: jax.Array, base: jax.Array, s: int, b: int) -> jax.Array:
    """Solve the block lower-triangular sweep at the heart of CA-BCD/CA-BDCD.

    Computes ``x`` with blocks ``x_j`` (j = 0..s-1, each of size ``b``) such that

        A[j,j] x_j = base_j - sum_{t<j} A[j,t] x_t

    which is exactly the unrolled recurrence (8)/(18) of the paper once the
    ``sb x sb`` Gram-plus-overlap matrix ``A`` has been formed (one all-reduce).
    Everything here is local and replicated: no communication.

    Args:
      A: ``(s*b, s*b)`` replicated matrix ``Gram + reg * Overlap`` (diagonal
        blocks are the per-iteration :math:`\\Gamma_{sk+j}` / :math:`\\Theta_{sk+j}`).
      base: ``(s*b,)`` right-hand side assembled from the deferred state
        ``(w_sk, alpha_sk, y)``.
      s, b: loop-blocking parameter and block size (static).

    Returns:
      ``(s*b,)`` concatenated block updates ``[dx_1; ...; dx_s]``.
    """
    sb = s * b
    A = A.reshape(s, b, s, b)

    def step(corr, j):
        # corr accumulates sum_t A[:, :, t_block] @ x_t for all already-solved t.
        rhs = jax.lax.dynamic_slice_in_dim(base, j * b, b) - jax.lax.dynamic_index_in_dim(
            corr.reshape(s, b), j, axis=0, keepdims=False)
        Ajj = jax.lax.dynamic_index_in_dim(A, j, axis=0, keepdims=False)  # (b, s, b)
        Ajj = jax.lax.dynamic_index_in_dim(Ajj, j, axis=1, keepdims=False)  # (b, b)
        xj = solve_spd(Ajj, rhs)
        # A[:, j_block] @ xj  -> contribution of block j to every later rhs.
        Acol = jax.lax.dynamic_index_in_dim(A, j, axis=2, keepdims=False)  # (s, b, b)
        corr = corr + (Acol @ xj).reshape(sb)
        return corr, xj

    _, xs = jax.lax.scan(step, jnp.zeros((sb,), base.dtype), jnp.arange(s))
    return xs.reshape(sb)

"""Block subproblem solves shared by the classical and CA solvers.

The paper solves each ``b x b`` subproblem "implicitly by first constructing
the Gram matrix and computing its Cholesky factorization" (section 2.1).  We do
exactly that; ``solve_spd`` is the single choke point so tests can property-check
it and the CA inner loop (block forward substitution) reuses it unchanged.

Two sweeps share the recurrence: :func:`block_forward_substitution` (the
ridge solvers' Eq. (8)/(18) inner loop) and
:func:`block_forward_substitution_prox` (the elastic-net variant of
arXiv:1712.06047: the same Cholesky solve per block, followed by a
soft-threshold of the candidate iterate).  The correction terms only
linearize the *smooth* part of the objective, which is exact regardless of
how each block's applied update was produced -- that is why the nonsmooth
prox slots into the communication-avoiding recurrence unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl


def solve_spd(A: jax.Array, rhs: jax.Array) -> jax.Array:
    """Solve ``A x = rhs`` for symmetric positive definite ``A`` via Cholesky."""
    chol = jsl.cholesky(A, lower=True)
    return jsl.cho_solve((chol, True), rhs)


# Relative diagonal-jitter escalation ladder (DESIGN.md section 7).  Level 0
# probes the unmodified matrix, so a healthy block pays no perturbation; the
# ladder is bounded above by max|diag(A)| itself -- past that the block carries
# no usable curvature and the solve is flagged instead of jittered further.
JITTER_LEVELS = (0.0, 1e-12, 1e-9, 1e-6, 1e-3, 1.0)


def choose_jitter(A: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Smallest relative diagonal jitter that makes ``A`` Cholesky-clean.

    Probes ``A + lev * scale * I`` for each level of :data:`JITTER_LEVELS`
    (``scale = max(|diag(A)|, 1)``) and returns ``(jitter, ok)``: the smallest
    absolute jitter whose Cholesky factor is finite with a strictly positive
    diagonal, and whether any level succeeded.  Traceable (no host branching):
    all levels are factored and the winner selected by ``where`` -- the ladder
    only runs on the engine's degraded path, never per clean outer step.
    """
    diag = jnp.abs(jnp.diagonal(A))
    scale = jnp.maximum(jnp.max(diag), jnp.asarray(1.0, A.dtype))
    eye = jnp.eye(A.shape[0], dtype=A.dtype)
    jitter = scale * jnp.asarray(JITTER_LEVELS[-1], A.dtype)
    ok = jnp.zeros((), bool)
    for lev in reversed(JITTER_LEVELS):
        j = scale * jnp.asarray(lev, A.dtype)
        chol = jsl.cholesky(A + j * eye, lower=True)
        good = jnp.all(jnp.isfinite(chol)) & jnp.all(jnp.diagonal(chol) > 0)
        jitter = jnp.where(good, j, jitter)
        ok = ok | good
    return jitter, ok


def solve_spd_jittered(A: jax.Array, rhs: jax.Array
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """NaN-free SPD solve: ``solve_spd`` hardened for singular / corrupted A.

    Sanitizes nonfinite entries, escalates diagonal jitter through
    :func:`choose_jitter`, and backstops any residual nonfinite solution with
    zeros.  Returns ``(x, jitter, ok)`` -- ``ok=False`` flags that even the
    bounded ladder could not produce a clean factorization (the zero update is
    then the correct degraded step: skip, don't corrupt).  A rank-deficient
    block from duplicate sampled indices at ``lam = 0`` is the canonical
    caller: plain ``solve_spd`` returns NaN there (regression-tested).
    """
    A = jnp.nan_to_num(A, nan=0.0, posinf=0.0, neginf=0.0)
    rhs = jnp.nan_to_num(rhs, nan=0.0, posinf=0.0, neginf=0.0)
    jitter, ok = choose_jitter(A)
    x = solve_spd(A + jitter * jnp.eye(A.shape[0], dtype=A.dtype), rhs)
    finite = jnp.all(jnp.isfinite(x))
    return jnp.where(finite, x, jnp.zeros_like(x)), jitter, ok & finite


def block_forward_substitution(A: jax.Array, base: jax.Array, s: int, b: int) -> jax.Array:
    """Solve the block lower-triangular sweep at the heart of CA-BCD/CA-BDCD.

    Computes ``x`` with blocks ``x_j`` (j = 0..s-1, each of size ``b``) such that

        A[j,j] x_j = base_j - sum_{t<j} A[j,t] x_t

    which is exactly the unrolled recurrence (8)/(18) of the paper once the
    ``sb x sb`` Gram-plus-overlap matrix ``A`` has been formed (one all-reduce).
    Everything here is local and replicated: no communication.

    Args:
      A: ``(s*b, s*b)`` replicated matrix ``Gram + reg * Overlap`` (diagonal
        blocks are the per-iteration :math:`\\Gamma_{sk+j}` / :math:`\\Theta_{sk+j}`).
      base: ``(s*b,)`` right-hand side assembled from the deferred state
        ``(w_sk, alpha_sk, y)``.
      s, b: loop-blocking parameter and block size (static).

    Returns:
      ``(s*b,)`` concatenated block updates ``[dx_1; ...; dx_s]``.
    """
    sb = s * b
    A = A.reshape(s, b, s, b)

    def step(corr, j):
        # corr accumulates sum_t A[:, :, t_block] @ x_t for all already-solved t.
        rhs = jax.lax.dynamic_slice_in_dim(base, j * b, b) - jax.lax.dynamic_index_in_dim(
            corr.reshape(s, b), j, axis=0, keepdims=False)
        Ajj = jax.lax.dynamic_index_in_dim(A, j, axis=0, keepdims=False)  # (b, s, b)
        Ajj = jax.lax.dynamic_index_in_dim(Ajj, j, axis=1, keepdims=False)  # (b, b)
        xj = solve_spd(Ajj, rhs)
        # A[:, j_block] @ xj  -> contribution of block j to every later rhs.
        Acol = jax.lax.dynamic_index_in_dim(A, j, axis=2, keepdims=False)  # (s, b, b)
        corr = corr + (Acol @ xj).reshape(sb)
        return corr, xj

    _, xs = jax.lax.scan(step, jnp.zeros((sb,), base.dtype), jnp.arange(s))
    return xs.reshape(sb)


def soft_threshold(u: jax.Array, tau: jax.Array) -> jax.Array:
    """Elementwise soft-threshold ``S(u, tau) = sign(u) max(|u| - tau, 0)`` --
    the proximal operator of ``tau ||.||_1``.  ``S(u, 0) == u`` bit-for-bit
    for finite floats (|u| - 0 is exact and sign(u)*|u| reconstructs u), so
    the lam1=0 path of the proximal solvers needs no special casing here."""
    return jnp.sign(u) * jnp.maximum(jnp.abs(u) - tau, 0)


def block_forward_substitution_prox(A: jax.Array, base: jax.Array, s: int,
                                    b: int, *, w0: jax.Array, tau: jax.Array,
                                    overlap: jax.Array) -> jax.Array:
    """The prox-aware block sweep of CA proximal BCD (arXiv:1712.06047).

    Per block ``j`` it runs the SAME recurrence as
    :func:`block_forward_substitution` -- the ``b x b`` Cholesky solve against
    the correction-adjusted right-hand side gives the candidate ridge update
    ``v_j`` -- and then soft-thresholds the candidate *iterate* instead of
    applying ``v_j`` directly:

        w_j^cur = w0_j + sum_{t<j} overlap[j,t] x_t        (duplicate indices)
        x_j     = S(w_j^cur + v_j, tau_j) - w_j^cur

    The applied update ``x_j`` (not the candidate ``v_j``) feeds the
    correction sums, so the smooth-part linearization stays exact and the
    s-step iterates match the classical (s=1) proximal schedule for any
    grouping of the index stream -- the nonsmooth term never enters the
    cross-block terms, it only reshapes each block's applied step locally.

    Args:
      A: ``(s*b, s*b)`` replicated ``Gram + reg * Overlap`` matrix (as in the
        ridge sweep).
      base: ``(s*b,)`` right-hand side at the outer-iteration start.
      s, b: loop-blocking parameter and block size (static).
      w0: ``(s*b,)`` values of the sampled coordinates at the outer start.
      tau: ``(s*b,)`` per-coordinate soft-thresholds (``lam1 / diag(A)``; for
        ``b = 1`` this makes each step the exact elastic-net coordinate
        minimizer).
      overlap: ``(s*b, s*b)`` duplicate-index matrix (``sampling.overlap_matrix``)
        so coordinates re-drawn in a later block see their updated value.

    Returns:
      ``(s*b,)`` concatenated applied updates ``[x_1; ...; x_s]``.
    """
    sb = s * b
    A = A.reshape(s, b, s, b)
    O = overlap.reshape(s, b, s, b)

    def step(carry, j):
        corr, wcorr = carry
        rhs = jax.lax.dynamic_slice_in_dim(base, j * b, b) - jax.lax.dynamic_index_in_dim(
            corr.reshape(s, b), j, axis=0, keepdims=False)
        Ajj = jax.lax.dynamic_index_in_dim(A, j, axis=0, keepdims=False)
        Ajj = jax.lax.dynamic_index_in_dim(Ajj, j, axis=1, keepdims=False)  # (b, b)
        vj = solve_spd(Ajj, rhs)
        wj = jax.lax.dynamic_slice_in_dim(w0, j * b, b) + jax.lax.dynamic_index_in_dim(
            wcorr.reshape(s, b), j, axis=0, keepdims=False)
        tj = jax.lax.dynamic_slice_in_dim(tau, j * b, b)
        xj = soft_threshold(wj + vj, tj) - wj
        Acol = jax.lax.dynamic_index_in_dim(A, j, axis=2, keepdims=False)  # (s, b, b)
        Ocol = jax.lax.dynamic_index_in_dim(O, j, axis=2, keepdims=False)
        corr = corr + (Acol @ xj).reshape(sb)
        wcorr = wcorr + (Ocol @ xj).reshape(sb)
        return (corr, wcorr), xj

    zeros = jnp.zeros((sb,), base.dtype)
    _, xs = jax.lax.scan(step, (zeros, zeros), jnp.arange(s))
    return xs.reshape(sb)

"""The one s-step engine behind every (CA-)BCD / (CA-)BDCD variant.

The paper's communication-avoiding transform is a single algorithmic idea
(DESIGN.md section 5): sample ``s`` coordinate blocks up front, build ONE
``sb x sb`` Gram packet at the single communication point, then run ``s``
communication-free inner solves by block forward substitution.  Everything
that distinguishes the primal from the dual solver -- which operand's rows
are sampled, the packet's scale/regularizer, the subproblem right-hand side,
which iterate the deferred update touches -- is data, not control flow.  This
module therefore factors the repo's former six hand-rolled solver loops
(``bcd``/``ca_bcd``, ``bdcd``/``ca_bdcd``, and the two shard_map variants)
into

* a :class:`Formulation` (primal / dual): the handful of problem-specific
  hooks above, bound to concrete operands by ``bind`` / ``bind_shard`` --
  the operand is a :class:`~repro.kernels.gram.PacketOperand` (array +
  layout + gather strategy, DESIGN.md section 5.2), so "which axis is
  sampled and how" is the operand's business, not the engine's: the primal
  binds row-major X, the dual binds COLUMN-major X in its original (d, n)
  layout (no pre-transpose), and a pre-materialized kernel matrix binds
  through the same dispatch with zero engine edits;
* a :class:`SolverPlan`: the execution knobs (b, s, backend ``impl``, kernel
  ``tiles``, ``fuse_packet``, ``unroll``, ``track_cond``) -- ``s=1`` *is* the
  classical variant, not a separate loop;
* ONE driver, :func:`s_step_solve`, whose outer ``lax.scan`` body
  (:func:`_outer_step`) is the only s-step hot loop in the repo.  The
  distributed path (:func:`s_step_solve_sharded`) wraps the *same* driver in
  ``shard_map`` and flips exactly one switch: the packet regularizer moves
  out of the kernel and an all-reduce (:func:`_packet_reduce`) is inserted at
  the one communication point.

``iters`` need not be a multiple of ``s``: the driver runs ``iters // s`` full outer
iterations through the scan and, when ``iters % s != 0``, one ragged final
outer iteration through the same body with ``s_k = iters % s`` -- the CA
identity holds for any grouping of the index stream, so the iterates still
match the classical schedule bit-for-bit in exact arithmetic.

New formulations plug in by implementing the Formulation hooks and
registering under a name -- no new loop, no new shard_map.  The proximal
elastic-net methods of arXiv:1712.06047 are ``repro.core.proximal`` (the
first formulation added *through* the registry; its nonsmooth update rides
the ``inner_sweep`` hook); the kernel BDCD of arXiv:2406.18001 is the next
candidate.  The registry (:func:`register_solver` / :func:`get_solver`,
keyed on ``(formulation, backend)``) is how launch scripts, benchmarks, and
examples select solvers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.kernels.gram import (ColMajorOperand, PacketOperand, PacketPlan,
                                RowMajorOperand, gram_packet_sampled,
                                panel_apply, panel_matvec)
from repro.kernels.gram.ops import _check_positive_int, _pad_axis

from .sampling import overlap_matrix, sample_blocks
from .subproblem import block_forward_substitution, choose_jitter


class SolveResult(NamedTuple):
    w: jax.Array          # (d,) primal iterate
    alpha: jax.Array      # (n,) auxiliary iterate (X^T w primal; dual vector)
    history: dict         # metric name -> (iters,) array (per inner iteration)
    metrics: dict = {}    # end-of-solve scalars (guard/recovery telemetry)


@dataclasses.dataclass(frozen=True)
class SolverContracts:
    """The communication/memory guarantees a formulation DECLARES -- and the
    static contract engine (``repro.analysis``) verifies against every
    registered lowering.

    The paper's headline result is a contract, not a number: CA-BCD/CA-BDCD
    synchronize exactly once per outer iteration (arXiv:1612.04003), the
    proximal variant inherits the same structure (arXiv:1712.06047), and the
    PR-2/PR-5 guarantees (panel never materializes; the dual binds the
    original layout with no transpose) are structural properties of the
    compiled HLO.  Each formulation states its invariants here instead of
    inheriting silent assumptions; ``python -m repro.analysis sweep`` lowers
    every ``(formulation, backend)`` registry entry and fails when a declared
    contract breaks.  A formulation without a ``contracts()`` hook FAILS the
    sweep -- declaring is mandatory, not optional.

    * ``sync_per_outer``: collectives per outer iteration on the sharded
      backend (1 for every paper formulation -- the single packet
      all-reduce).  A future pipelined-collective formulation would declare
      its own count here rather than silently widening the budget.
    * ``collective_kinds``: the only collective opcodes allowed to appear in
      the sharded lowering at all.
    * ``local_collective_free``: the local backend must lower with ZERO
      cross-device collectives.
    * ``operand_transpose_free``: no HLO transpose of the bound operand's
      (local) array anywhere in the sharded solve body -- the PR-5 "no dual
      pre-transpose" guarantee, checked shape-against-shape.
    * ``panel_free_impls``: kernel backends whose lowering must never
      materialize the sampled ``(sb, contraction)`` panel outside a Pallas
      custom-call (the ``impl="ref"`` path gathers the panel by design, so
      it is not listed).
    * ``f64_packet``: under the x64 test path every collective must move f64
      words (the packet may not silently downcast accumulation).
    * ``health_in_packet``: the formulation supports ``SolverPlan.guard``
      with the per-outer-step health word riding the ONE packet all-reduce
      (DESIGN.md section 7) -- the analysis engine additionally lowers the
      guard-enabled solver and asserts the collective count is UNCHANGED
      (exactly ``sync_per_outer * H``): the zero-extra-collectives guarantee.
    * ``lowering_kwargs``: extra solver kwargs ((key, value) pairs) the
      analysis engine passes when lowering this formulation abstractly, so
      formulation-specific code paths (e.g. the proximal soft-threshold at
      ``lam1 > 0``) are the ones verified.
    * ``tenant_batched``: the formulation supports the batched multi-tenant
      engine (:func:`s_step_solve_batched`) -- its per-tenant coefficients
      flow through ``bind``/``dataclasses.replace`` under ``vmap`` and its
      sharded batched lowering keeps ``sync_per_outer`` collectives per
      outer step INDEPENDENT of the tenant count, with the Gram part of the
      packet payload not scaled by T (DESIGN.md section 8; the analysis
      sweep lowers batched cases at T in {1, 8, 64} and checks both).
    * ``pipelined_collective_kinds`` / ``pipelined_hops``: the wire schedule
      of the PIPELINED backend (``SolverPlan.wire == "ring"``, DESIGN.md
      section 9).  The kinds tuple is the only collective opcodes allowed in
      the pipelined lowering; ``pipelined_hops`` is the per-sync op count as
      an affine law ``(a, c)`` meaning ``sum_i (a * P_i + c)`` over the mesh
      axis sizes -- the default ``(2, -2)`` is the two-phase ring's
      ``2 (P_i - 1)`` collective-permute hops per axis.  The analysis sweep
      computes the expected count from the mesh it lowers on
      (:func:`ring_hops`), so a backend with a different decomposition
      declares its law here instead of hand-editing count asserts.
    """
    sync_per_outer: int = 1
    collective_kinds: tuple = ("all-reduce",)
    local_collective_free: bool = True
    operand_transpose_free: bool = True
    panel_free_impls: tuple = ("pallas", "pallas_interpret")
    f64_packet: bool = True
    health_in_packet: bool = False
    lowering_kwargs: tuple = ()
    tenant_batched: bool = False
    pipelined_collective_kinds: tuple = ("collective-permute",)
    pipelined_hops: tuple = (2, -2)


@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """Everything the engine needs to know besides the problem data.

    ``b`` is the paper's block size (b' for the dual), ``s`` the loop-blocking
    parameter (s=1 recovers the classical algorithm).  ``impl``/``tiles``
    select the Gram-packet kernel backend and its (bm, bk) -- collapsed into
    one :class:`~repro.kernels.gram.PacketPlan` handed to every kernel call.
    ``fuse_packet`` picks the wire layout of the distributed reduction (see
    :func:`_packet_reduce`); ``unroll`` is forwarded to the outer scan;
    ``track_cond`` records cond(Gram) per outer iteration in the history.

    ``wire`` picks the reduction SCHEDULE of the distributed backends:
    ``"psum"`` (default) is the monolithic packet all-reduce; ``"ring"`` is
    the pipelined backend's collective-permute decomposition -- a two-phase
    ring of ``ppermute`` hops per mesh axis with the next outer step's Gram
    contraction software-pipelined between the phases (DESIGN.md section 9).
    The iterates agree to f64 ~1e-12 (the ring's summation order differs
    from psum's tree, so bit-for-bit is not guaranteed across wires).

    ``guard`` enables the in-scan health guards (DESIGN.md section 7): a
    per-outer-step health word rides the ONE packet reduction (zero extra
    collectives) and a tripped guard degrades the step -- adaptive diagonal
    jitter or a skipped update -- instead of corrupting ``s`` deferred
    iterations.  ``guard_boost`` is the divergence/magnitude envelope margin
    (trip when the tracked quantity exceeds ``boost x`` its running floor);
    ``guard_cond_max`` caps the Gram-diagonal ratio condition proxy (``None``
    picks ``0.1 / eps(dtype)``).  ``fault`` attaches a test-only
    :class:`repro.faults.FaultPlan` (duck-typed: anything with
    ``apply_packet`` / ``apply_health``) injected inside the hot loop.

    ``tenants`` pins the tenant-axis width of a batched solve (DESIGN.md
    section 8): ``None`` means "whatever the :class:`TenantBatch` carries";
    a pinned value makes the plan itself the compile-cache key for a
    (bucket, formulation) pair -- the batched entry points reject a batch
    whose width disagrees instead of silently recompiling.
    """
    b: int
    s: int = 1
    impl: str | None = None
    tiles: tuple[int, int] | None = None
    fuse_packet: bool = True
    unroll: int = 1
    track_cond: bool = False
    guard: bool = False
    guard_boost: float = 1e4
    guard_cond_max: float | None = None
    fault: object | None = None
    tenants: int | None = None
    wire: str = "psum"

    def __post_init__(self):
        # Fail fast at plan construction: a typo'd impl or a zero tile would
        # otherwise only surface at the first kernel call inside the jitted
        # scan (or, worse, silently fall through to the autotuned tiles).
        for name in ("b", "s", "unroll"):
            _check_positive_int(f"SolverPlan.{name}", getattr(self, name))
        if self.tiles is not None and len(self.tiles) != 2:
            raise ValueError(
                f"SolverPlan.tiles={self.tiles!r} must be a (bm, bk) pair")
        if not isinstance(self.guard, bool):
            raise ValueError(f"SolverPlan.guard={self.guard!r} must be a bool")
        if not self.guard_boost > 1:
            raise ValueError(
                f"SolverPlan.guard_boost={self.guard_boost!r} must be > 1")
        if self.guard_cond_max is not None and not self.guard_cond_max > 1:
            raise ValueError(
                f"SolverPlan.guard_cond_max={self.guard_cond_max!r} "
                "must be > 1 (or None for the dtype default)")
        if self.fault is not None and not (
                hasattr(self.fault, "apply_packet")
                and hasattr(self.fault, "apply_health")):
            raise ValueError(
                f"SolverPlan.fault={self.fault!r} must provide "
                "apply_packet/apply_health (see repro.faults.FaultPlan)")
        if self.tenants is not None:
            _check_positive_int("SolverPlan.tenants", self.tenants)
        if self.wire not in ("psum", "ring"):
            raise ValueError(
                f"SolverPlan.wire={self.wire!r} must be 'psum' or 'ring'")
        self.packet  # PacketPlan.make validates impl and the tile values

    @property
    def packet(self) -> PacketPlan:
        return PacketPlan.make(impl=self.impl, tiles=self.tiles)


@runtime_checkable
class BoundFormulation(Protocol):
    """A formulation bound to concrete operands (global or one shard's).

    ``operand`` is a :class:`~repro.kernels.gram.PacketOperand` -- the array
    plus its layout and gather strategy (DESIGN.md section 5.2).  The engine
    samples the operand's index space; the packet it builds is
    ``G = scale * Y Y^T + reg * I`` and ``r = scale_r * Y u`` for the
    operand's sampled panel ``Y(flat)`` (rows of the array for the primal's
    row-major operand, columns of the ORIGINAL layout for the dual's
    column-major operand, gathered pre-formed products for a materialized
    kernel matrix) and ``u = packet_vector(carry)``.  ``reg`` is also the
    coefficient of the duplicate-index overlap term, which is why a single
    scalar serves both the fused local diagonal and the post-reduce
    correction.

    ``inner_sweep`` owns the subproblem solve: given the replicated
    ``sb x sb`` system ``A`` and right-hand side ``base`` it returns the
    ``sb`` applied block updates.  The ridge formulations delegate to
    :func:`~repro.core.subproblem.block_forward_substitution`; nonsmooth
    formulations (the proximal elastic net) run the prox-aware variant --
    the hook exists precisely so a formulation can reshape each block's
    applied step without touching the engine's one hot-loop body.
    """
    operand: PacketOperand

    @property
    def scale(self) -> float: ...
    @property
    def scale_r(self) -> float | None: ...
    @property
    def reg(self) -> float: ...
    def init_carry(self, axes: tuple | None = None) -> tuple: ...
    def packet_vector(self, carry) -> jax.Array: ...
    def base(self, r: jax.Array, carry, flat: jax.Array) -> jax.Array: ...
    def inner_sweep(self, A: jax.Array, base: jax.Array, s_k: int, b: int,
                    flat: jax.Array, carry,
                    overlap: jax.Array | None) -> jax.Array: ...
    def update(self, carry, idx: jax.Array, dx: jax.Array,
               pp: PacketPlan) -> tuple: ...
    def metrics(self, carry) -> dict: ...


class Formulation(Protocol):
    """A problem formulation: how to bind data to a :class:`BoundFormulation`
    and how its operands shard (DESIGN.md section 5.3).  ``operand_layout``
    names the PacketOperand kind ``bind``/``bind_shard`` produce (DESIGN.md
    section 5.2) -- introspection only (dry-runs, benchmarks); the engine
    itself dispatches through the operand object."""
    name: str
    operand_layout: str

    def contracts(self) -> SolverContracts: ...
    def sample_dim(self, d: int, n: int) -> int: ...
    def bind(self, X, y, lam, *, x0=None, w_ref=None) -> BoundFormulation: ...
    def pad_shards(self, X, y, n_shards: int) -> tuple: ...
    def bind_shard(self, Xl, yl, lam, *, d: int, n: int,
                   x0=None) -> BoundFormulation: ...
    def dist_in_specs(self, axis) -> tuple: ...
    def dist_out_specs(self, axis) -> tuple: ...
    def dist_finalize(self, w, alpha, d: int, n: int) -> tuple: ...


# --------------------------------------------------------------------------
# Shared metric helpers
# --------------------------------------------------------------------------

def _objective_from_alpha(alpha, w, y, lam):
    # alpha == X^T w is maintained by the residual-form recurrence, so the
    # objective costs O(n + d) per iteration instead of O(dn).
    n = alpha.shape[0]
    r = alpha - y
    return 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)


def _sol_err(w, w_ref):
    return jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)


def _fit_residual(alpha, y):
    # ||alpha - y|| / (1 + ||y||): the O(n) data-fit proxy the batched
    # engine's early-retirement mask thresholds (DESIGN.md section 8).  A
    # relative statistic, monotone along the solve, cheap enough to ride
    # every outer step; NOT a stationarity certificate (the ridge optimum
    # has a nonzero fit residual), so retirement tolerances are calibrated
    # per workload, not read as gradient norms.
    return jnp.linalg.norm(alpha - y) / (1.0 + jnp.linalg.norm(y))


# --------------------------------------------------------------------------
# Primal formulation: min_w lam/2 ||w||^2 + 1/(2n) ||X^T w - y||^2
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BoundPrimal:
    """Algorithm 1/2 hooks; ``operand`` is the row-major X (d, n) or a column
    shard of it.

    Packet: Gamma = Y Y^T / n + lam I with Y = X[flat, :] and the residual
    contribution Y (y - alpha) / n of the Eq. (7)/(8) rhs; base subtracts the
    lam w term; the inner update is w[idx] += dw, alpha += Y_j^T dw (Eqs. 5,
    9-10).  All expressions are layout-neutral: on a column shard (y and
    alpha local, w replicated) they compute exactly the local contribution.
    """
    operand: PacketOperand
    y: jax.Array            # aligned with operand's columns
    lam: float
    n: int                  # GLOBAL data-point count (scales use it)
    d: int
    w0: jax.Array | None = None
    w_ref: jax.Array | None = None

    @property
    def scale(self):
        return 1.0 / self.n

    @property
    def scale_r(self):
        return None         # defaults to scale

    @property
    def reg(self):
        return self.lam

    def init_carry(self, axes=None):
        X = self.operand.array
        w = jnp.zeros((self.d,), X.dtype) if self.w0 is None else self.w0
        if axes is not None:
            # alpha is device-varying (each shard owns a slice of R^n); w is
            # replicated.  A warm-started w derives its local alpha slice as
            # ``w @ Xl`` -- no transpose, no gather -- which is what lets the
            # supervised restart path re-enter the sharded solve from a
            # checkpointed iterate (DESIGN.md section 7).
            if self.w0 is not None:
                return w, w @ X
            return w, compat.pvary(jnp.zeros(self.y.shape, X.dtype), axes)
        # contract: allow-transpose -- one-time warm-start init, not the
        # solve path (the hot loop's transpose-free-ness is what the HLO
        # contract pass pins; repro/analysis/lint.py enforces this comment).
        alpha = X.T @ w if self.w0 is not None else jnp.zeros((self.n,), X.dtype)
        return w, alpha

    def packet_vector(self, carry):
        return self.y - carry[1]

    def base(self, r, carry, flat):
        # Eq. (7)/(8) rhs.  The lam*w mul/sub seam may fma-contract, which
        # is fine BECAUSE every context that evaluates it is a compiled body
        # running this same graph (the drivers share _assemble_subproblem
        # and the ragged tail is scanned) -- see _assemble_subproblem.
        return r - self.lam * carry[0][flat]

    def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
        return block_forward_substitution(A, base, s_k, b)

    def update(self, carry, idx, dx, pp):
        w, alpha = carry
        w = w.at[idx].add(dx)                              # Eq. (9)
        alpha = alpha + panel_apply(self.operand, idx, dx, plan=pp)  # Eq. (5)/(10)
        return w, alpha

    def metrics(self, carry):
        w, alpha = carry
        m = {"objective": _objective_from_alpha(alpha, w, self.y, self.lam),
             "residual": _fit_residual(alpha, self.y)}
        if self.w_ref is not None:
            m["sol_err"] = _sol_err(w, self.w_ref)
        return m


class PrimalRidge:
    """(CA-)BCD: samples features (rows of X); 1D-block-column layout."""
    name = "primal"
    operand_layout = "rows"

    def contracts(self):
        # Theorem 1/6 structure: ONE fused packet all-reduce per outer
        # iteration, nothing else on the wire; row-major operand, no
        # transpose, panel-free kernel path.  The health word rides that
        # same all-reduce (guard mode adds zero collectives).  All the
        # scales are tenant-independent, so the batched engine shares the
        # fully-scaled Gram across tenants.
        return SolverContracts(health_in_packet=True, tenant_batched=True)

    def sample_dim(self, d, n):
        return d

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        d, n = X.shape
        return _BoundPrimal(operand=RowMajorOperand(X), y=y, lam=lam, n=n,
                            d=d, w0=x0, w_ref=w_ref)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 1), _pad_to(y, n_shards, 0)

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        return _BoundPrimal(operand=RowMajorOperand(Xl), y=yl, lam=lam, n=n,
                            d=d, w0=x0)

    def dist_in_specs(self, axis):
        return P(None, axis), P(axis), P(None)

    def dist_out_specs(self, axis):
        return P(None), P(axis)

    def dist_finalize(self, w, alpha, d, n):
        return w, alpha[:n]


# --------------------------------------------------------------------------
# Dual formulation: min_alpha lam/2 ||X alpha/(lam n)||^2 + 1/(2n) ||alpha + y||^2
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BoundDual:
    """Algorithm 3/4 hooks; ``operand`` is the column-major X (d, n) -- or a
    row shard Xl (dl, n) -- in its ORIGINAL layout.  The dual samples
    *columns* of X; the column-gather operand (``sampled_colmajor.py``) makes
    that a first-class access pattern, so no pre-transpose and no second
    resident copy of the dataset exist anywhere in the dual solve path
    (the PR-2..4 ``Xl.T`` workaround this replaces is discussed in
    ``repro.core.bdcd``).

    Packet: Theta = Y^T Y / (lam n^2) + I/n with Y = X[:, flat] plus the RAW
    projection Y^T w (scale_r=1); base assembles Eq. (17)/(18); the inner
    update is alpha[idx] += da, w -= Y_j da / (lam n) (Eqs. 15, 19-20).  On a
    row shard (w local, alpha and y replicated) the same expressions compute
    the local contribution.
    """
    operand: PacketOperand
    y: jax.Array            # (n,), replicated in the distributed layout
    lam: float
    n: int                  # GLOBAL data-point count
    X: jax.Array | None = None      # full X, for init + metrics (local mode)
    alpha0: jax.Array | None = None
    w_ref: jax.Array | None = None
    # Pinned derived constants (see DualRidge.tenant_constants): with a
    # python-float lam the properties below compute these in f64 host
    # arithmetic, but a traced per-tenant lam would round every intermediate
    # to f32 -- an ulp off the single solve.  The batched engine pins the
    # host-computed values here instead.
    scale_c: object = None
    lam_n: object = None

    @property
    def scale(self):
        if self.scale_c is not None:
            return self.scale_c
        return 1.0 / (self.lam * self.n * self.n)

    @property
    def _div(self):
        """The Eq. (15)/(19) divisor lam*n, host-exact when pinned.  Always
        returned as an optimization-barriered runtime value: an embedded
        python-float divisor gets constant-folded by XLA into a reciprocal
        multiply (an ulp off a true division), while the batched engine's
        pinned per-tenant divisor is a traced array that divides for real --
        the barrier forces the true division in every context."""
        div = self.lam * self.n if self.lam_n is None else self.lam_n
        if isinstance(div, (int, float)):
            div = jax.lax.optimization_barrier(
                jnp.asarray(div, self.operand.dtype))
        return div

    @property
    def scale_r(self):
        return 1.0

    @property
    def reg(self):
        return 1.0 / self.n

    def init_carry(self, axes=None):
        dtype = self.operand.dtype
        if axes is not None:
            # w is device-varying (each shard owns a slice of R^d); alpha is
            # replicated.  The operand's contraction length IS the local dl.
            # A warm-started alpha derives its local w slice straight from
            # the ORIGINAL (dl, n) layout -- checkpointed restarts re-enter
            # the sharded solve transpose-free (DESIGN.md section 7).
            if self.alpha0 is not None:
                Xl = self.operand.array
                q = jax.lax.optimization_barrier(Xl @ self.alpha0)
                return -q / self._div, self.alpha0
            wl = compat.pvary(jnp.zeros((self.operand.contraction,), dtype),
                              axes)
            return wl, jnp.zeros((self.n,), dtype)
        alpha = jnp.zeros((self.n,), dtype) if self.alpha0 is None else self.alpha0
        q = jax.lax.optimization_barrier(self.X @ alpha)
        return -q / self._div, alpha

    def packet_vector(self, carry):
        return carry[0]

    def base(self, u, carry, flat):
        w, alpha = carry
        num = u - alpha[flat] - self.y[flat]
        # Eq. (17)/(18).  Barriered divisor for the same reason as _div:
        # a python-int n constant-folds to a reciprocal multiply inside
        # compiled bodies but divides for real eagerly -- the barrier
        # forces the true division in every context.
        return num / jax.lax.optimization_barrier(
            jnp.asarray(self.n, num.dtype))

    def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
        return block_forward_substitution(A, base, s_k, b)

    def update(self, carry, idx, dx, pp):
        w, alpha = carry
        alpha = alpha.at[idx].add(dx)                      # Eq. (20)
        # Eq. (15)/(19): w -= X[:, idx] @ dx / (lam n) -- the column-major
        # operand's Y^T v, straight from the original layout.  The barriers
        # pin the rounding sequence (gather-apply, then divide, then
        # subtract): XLA otherwise fuses the division into whichever
        # producer the surrounding context offers, and the single-solve scan
        # and the tenant-batched scan offer different ones -- an ulp apart.
        ap = jax.lax.optimization_barrier(
            panel_apply(self.operand, idx, dx, plan=pp))
        w = w - jax.lax.optimization_barrier(ap / self._div)
        return w, alpha

    def metrics(self, carry):
        # Primal objective evaluated at the dual-generated primal iterate w:
        # X^T w is O(dn), affordable at the paper's figure sizes; the
        # distributed fast path skips metrics entirely.
        w, alpha = carry
        n = self.n
        # contract: allow-transpose -- metric evaluation on the full X
        # (local mode only; the distributed fast path skips metrics and the
        # HLO pass verifies its lowering is transpose-free).
        r = self.X.T @ w - self.y
        m = {"objective": 0.5 / n * (r @ r) + 0.5 * self.lam * (w @ w),
             # ||X^T w - alpha - y|| -> 0 at the dual optimum (alpha tracks
             # the primal residual X^T w - y), so unlike the primal's proxy
             # this one IS a convergence residual; local mode only (uses X).
             "residual": jnp.linalg.norm(r - alpha)
             / (n * (1.0 + jnp.linalg.norm(self.y)))}
        if self.w_ref is not None:
            m["sol_err"] = _sol_err(w, self.w_ref)
        return m


class DualRidge:
    """(CA-)BDCD: samples data points (columns of X) from the ORIGINAL
    (d, n) layout via the column-major operand; 1D-block-row layout."""
    name = "dual"
    operand_layout = "cols"

    def contracts(self):
        # Theorem 2/7 structure, plus the PR-5 guarantee this formulation
        # exists to keep: the ORIGINAL (d, n) layout is never transposed
        # anywhere in the sharded solve body.  Guard mode keeps both: the
        # health word rides the one packet all-reduce.  The Gram scale
        # 1/(lam n^2) is per-tenant, so the batched engine contracts the
        # RAW Gram once and scales it per tenant post-reduce.
        return SolverContracts(health_in_packet=True, tenant_batched=True)

    def sample_dim(self, d, n):
        return n

    def tenant_constants(self, lam: float, d: int, n: int) -> dict:
        # Host-exact per-tenant derived constants for the batched engine:
        # computed in f64 python arithmetic from a concrete lam (exactly as
        # the single solve's properties do) and pinned on the bound, so the
        # traced per-tenant lam never rounds an intermediate to f32.
        return {"scale_c": 1.0 / (lam * n * n), "lam_n": lam * n}

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        return _BoundDual(operand=ColMajorOperand(X), y=y, lam=lam,
                          n=X.shape[1], X=X, alpha0=x0, w_ref=w_ref)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 0), y

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        # The ORIGINAL (dl, n) shard, zero copies: the column-major operand
        # gathers sampled columns in place (pre-PR-5 this was ``Xl.T``,
        # doubling the resident dataset for the length of the solve).
        return _BoundDual(operand=ColMajorOperand(Xl), y=yl, lam=lam, n=n,
                          alpha0=x0)

    def dist_in_specs(self, axis):
        return P(axis, None), P(None), P(None)

    def dist_out_specs(self, axis):
        return P(axis), P(None)

    def dist_finalize(self, w, alpha, d, n):
        return w[:d], alpha


FORMULATIONS: dict[str, Formulation] = {
    "primal": PrimalRidge(),
    "dual": DualRidge(),
}


def register_formulation(form: Formulation) -> Formulation:
    """Publish a Formulation under its ``name`` so the string-keyed entry
    points (``s_step_solve(\"proximal\", ...)``, ``lower_solver``, the
    benchmark harness) can resolve it.  New formulations call this next to
    their ``register_solver`` entries (e.g. ``repro.core.proximal``)."""
    FORMULATIONS[form.name] = form
    return form


# --------------------------------------------------------------------------
# The communication point
# --------------------------------------------------------------------------

def _axes(axis) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


def psum_variadic(leaves, axis):
    """ONE all-reduce for any list of same-dtype arrays: ravel, concatenate,
    psum, split.  This is the explicit variadic packet: XLA builds without
    the all-reduce combiner would otherwise emit one op per array (the
    ROADMAP's 2-all-reduces-per-iteration artifact), which breaks the
    latency accounting the collective-count tests pin down."""
    shapes = [x.shape for x in leaves]
    flat = jnp.concatenate([x.ravel() for x in leaves])
    red = jax.lax.psum(flat, axis)
    out, off = [], 0
    for sh in shapes:
        size = math.prod(sh)
        out.append(red[off:off + size].reshape(sh))
        off += size
    return out


def ring_hops(axis_sizes, law: tuple = (2, -2)) -> int:
    """Collective-permute ops per sync of the ring wire: the affine law
    ``sum_i (a * P_i + c)`` a formulation declares via
    ``SolverContracts.pipelined_hops``.  The default ``(2, -2)`` is the
    two-phase ring's ``2 (P_i - 1)`` hops per mesh axis (a reduce-scatter
    and an all-gather round of ``P_i - 1`` hops each); size-1 axes
    contribute zero hops under that law, matching the implementation's
    skip."""
    a, c = law
    return sum(a * p + c for p in axis_sizes)


def _ring_reduce_scatter(flat, name, P):
    """Phase one of the ring: ``P - 1`` ``ppermute`` hops of one chunk each,
    accumulating around the ring.  After the last hop this shard owns the
    fully-reduced chunk ``(me + 1) % P``.  Every chunk ``j`` is summed along
    ONE fixed chain (shard j's value, then j+1's, then j+2's, ...) no matter
    which shard ends up owning it, so the reduced chunks are deterministic
    bytes -- the property phase two turns into replicated-carry consistency."""
    pad = (-flat.shape[0]) % P
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    buf = flat.reshape(P, flat.shape[0] // P)
    me = jax.lax.axis_index(name)
    perm = [(i, (i + 1) % P) for i in range(P)]
    for t in range(P - 1):
        send = jnp.take(buf, (me - t) % P, axis=0)
        recv = jax.lax.ppermute(send, name, perm)
        buf = buf.at[(me - t - 1) % P].add(recv)
    return buf, me


def _ring_all_gather(buf, me, name, P):
    """Phase two: circulate the reduced chunks another ``P - 1`` hops.
    Received chunks are stored VERBATIM (no arithmetic), so every shard ends
    holding the same bytes phase one produced -- replicated carries stay
    replicated without a psum."""
    perm = [(i, (i + 1) % P) for i in range(P)]
    for t in range(P - 1):
        send = jnp.take(buf, (me + 1 - t) % P, axis=0)
        recv = jax.lax.ppermute(send, name, perm)
        buf = buf.at[(me - t) % P].set(recv)
    return buf.reshape(-1)


def ring_reduce_variadic(leaves, axis, axis_sizes, overlap_fn=None):
    """The pipelined wire: the SAME variadic packet as :func:`psum_variadic`,
    reduced by a two-phase ring of ``ppermute`` hops per mesh axis instead of
    one monolithic psum -- ``2 (P_i - 1)`` collective-permutes per axis,
    each moving a ``1/P_i`` chunk, with NO all-reduce anywhere.

    ``overlap_fn`` (nullary) is the software-pipelining hook: it is invoked
    between the first ring's reduce-scatter and all-gather phases, and its
    result is returned alongside the reduced leaves.  The hook's compute has
    ZERO data dependence on the in-flight reduction (the pipelined driver
    passes the NEXT outer step's Gram contraction, which depends only on the
    index stream), which is what frees a latency-hiding scheduler to run it
    under the hops -- the overlap ``cost_model.overlap_ratio`` accounts.

    Numerics: each chunk is summed along one fixed ring chain and broadcast
    verbatim, so all shards hold IDENTICAL reduced bytes (replicated carries
    stay replicated), but the association differs from psum's tree -- equal
    to the psum wire to f64 ~1e-12, not bit-for-bit.
    """
    shapes = [x.shape for x in leaves]
    flat = jnp.concatenate([x.ravel() for x in leaves])
    size = flat.shape[0]
    extra = None
    for name, P in zip(_axes(axis), axis_sizes):
        if P == 1:
            continue
        buf, me = _ring_reduce_scatter(flat, name, P)
        if extra is None and overlap_fn is not None:
            extra = overlap_fn()
        flat = _ring_all_gather(buf, me, name, P)[:size]
    if extra is None and overlap_fn is not None:
        extra = overlap_fn()        # degenerate all-size-1 mesh: no hops
    out, off = [], 0
    for sh in shapes:
        sz = math.prod(sh)
        out.append(flat[off:off + sz].reshape(sh))
        off += sz
    return out, extra


def _packet_reduce(G_local, r_local, axis, fuse, health=None):
    """THE sync point: one all-reduce per outer iteration, either as the
    fused sb x (sb+1) Gram||residual operand (``fuse_packet=True``, ours) or
    as the explicit variadic packet of the two separate operands
    (``fuse_packet=False``, the paper's two logical reductions packed into
    one wire message).

    Guard mode hands in the per-shard ``health`` word, which rides the SAME
    wire message through the variadic packet regardless of ``fuse`` -- the
    sharded health guards add ZERO extra collectives (the ``health_in_packet``
    contract, statically verified by the analysis sweep).  Returns
    ``(G, r, health)`` with ``health=None`` when no word was handed in.
    """
    if axis is None:
        return G_local, r_local, health
    if health is not None:
        G, r, h = psum_variadic([G_local, r_local, health], axis)
        return G, r, h
    if fuse:
        sb = G_local.shape[0]
        packet = jax.lax.psum(
            jnp.concatenate([G_local, r_local[:, None]], axis=1), axis)
        return packet[:, :sb], packet[:, sb], None
    G, r = psum_variadic([G_local, r_local], axis)
    return G, r, None


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple of ``mult``.  Zero rows/columns
    of X contribute nothing to Grams, residuals or updates, and the sampler
    only draws indices < the true size, so padding is exact (tested)."""
    return _pad_axis(x, mult, axis)


# --------------------------------------------------------------------------
# In-scan health guards (DESIGN.md section 7)
# --------------------------------------------------------------------------

# Guard-trip reason bits (``SolveResult.metrics["guard_first_reason"]``).
GUARD_NONFINITE = 1    # NaN/Inf in the packet or the solver carry
GUARD_SHARD_LOSS = 2   # a shard's presence flag missing from the reduction
GUARD_DIVERGENCE = 4   # packet-vector norm blew past its running envelope
GUARD_MAGNITUDE = 8    # packet magnitude blew past its envelope (bit flips)
GUARD_COND = 16        # Gram-diagonal condition proxy tripped
GUARD_BREAKDOWN = 32   # the inner sweep itself produced nonfinite updates

_HEALTH_WORDS = 5


class GuardState(NamedTuple):
    """Replicated guard telemetry threaded through the outer scan.  The
    envelopes are running minima of ``1 + ||u||^2`` / ``1 + max|G_local|``
    (the +1 floors them so an iterate growing from exactly zero -- the dual's
    cold-started w -- cannot arm a zero envelope); divergence/magnitude
    guards therefore need one clean outer step to arm."""
    env_r: jax.Array        # running floor of 1 + packet-vector norm^2
    env_g: jax.Array        # running floor of 1 + max |G_local|
    trips: jax.Array        # int32 count of tripped outer steps
    first_trip: jax.Array   # int32 outer index of the first trip (-1: clean)
    first_reason: jax.Array  # int32 GUARD_* bitmask at the first trip
    max_jitter: jax.Array   # largest diagonal jitter applied by a rescue


def _guard_init(dtype) -> GuardState:
    inf = jnp.asarray(jnp.inf, dtype)
    return GuardState(inf, inf, jnp.zeros((), jnp.int32),
                      jnp.full((), -1, jnp.int32), jnp.zeros((), jnp.int32),
                      jnp.zeros((), dtype))


def _guard_metrics(gstate: GuardState) -> dict:
    return {"guard_trips": gstate.trips,
            "guard_first_trip": gstate.first_trip,
            "guard_first_reason": gstate.first_reason,
            "guard_max_jitter": gstate.max_jitter}


def _health_local(Gl, rl, carry, u, dtype):
    """The per-shard health word (length ``_HEALTH_WORDS``) that rides the
    packet psum: [nonfinite count in (G, r); nonfinite count in the carry;
    local packet-vector squared norm; shard presence; max |G_local|].  All
    entries are sums, so ONE psum yields the global verdicts."""
    nonfinite = ((~jnp.isfinite(Gl)).sum()
                 + (~jnp.isfinite(rl)).sum()).astype(dtype)
    carry_bad = sum(((~jnp.isfinite(leaf)).sum()
                     for leaf in jax.tree.leaves(carry)),
                    jnp.zeros((), jnp.int32)).astype(dtype)
    r2 = jnp.sum(u * u).astype(dtype)
    present = jnp.ones((), dtype)
    gmax = jnp.max(jnp.abs(Gl)).astype(dtype)
    return jnp.stack([nonfinite, carry_bad, r2, present, gmax])


def _guarded_sweep(bound, plan, A, base, s_k, b, flat, carry, O, h, gstate,
                   step, n_shards, dtype):
    """Check the reduced health word, then solve -- degrading instead of
    corrupting.  Every decision derives from the replicated post-psum word
    (plus the replicated A / dxs), so all shards branch identically.

    The degradation ladder's first rung lives here: nonfinite packets,
    missing shards and bit-flip-scale magnitudes SKIP the update (dxs = 0 --
    one outer step of progress lost, carry untouched); divergence, the
    condition proxy and an inner-sweep breakdown RESCUE it (sanitize, pick
    the smallest working diagonal jitter, re-sweep).  Rung two (the s=1
    tail) is driver-level; rung three (restart) is the supervisor's.
    """
    i32 = jnp.int32
    boost = jnp.asarray(plan.guard_boost, dtype)
    one = jnp.asarray(1.0, dtype)
    bad_nonfinite = (h[0] + h[1]) > 0
    bad_shard = h[3] != n_shards
    r_now, g_now = one + h[2], one + h[4]
    bad_div = r_now > boost * gstate.env_r
    bad_mag = g_now > boost * gstate.env_g
    diag = jnp.diagonal(A)
    dmin = jnp.min(diag)
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    cond_max = (plan.guard_cond_max if plan.guard_cond_max is not None
                else 0.1 / float(jnp.finfo(dtype).eps))
    bad_cond = (dmin <= 0) | (
        jnp.max(diag) / jnp.maximum(dmin, tiny) > cond_max)
    skip = bad_nonfinite | bad_shard | bad_mag
    dxs = bound.inner_sweep(A, base, s_k, b, flat, carry, O)
    bad_solve = ~jnp.all(jnp.isfinite(dxs))
    rescue = (bad_div | bad_cond | bad_solve) & ~skip

    def _rescue(_):
        As = jnp.nan_to_num(A, nan=0.0, posinf=0.0, neginf=0.0)
        bs = jnp.nan_to_num(base, nan=0.0, posinf=0.0, neginf=0.0)
        jitter, _ok = choose_jitter(As)
        eye = jnp.eye(s_k * b, dtype=dtype)
        dj = bound.inner_sweep(As + jitter * eye, bs, s_k, b, flat, carry, O)
        return jnp.where(jnp.isfinite(dj), dj, jnp.zeros_like(dj)), jitter

    dxs, jitter = jax.lax.cond(
        rescue, _rescue, lambda _: (dxs, jnp.zeros((), dtype)), None)
    dxs = jnp.where(skip, jnp.zeros_like(dxs), dxs)
    tripped = skip | rescue
    reason = (bad_nonfinite.astype(i32) * GUARD_NONFINITE
              + bad_shard.astype(i32) * GUARD_SHARD_LOSS
              + bad_div.astype(i32) * GUARD_DIVERGENCE
              + bad_mag.astype(i32) * GUARD_MAGNITUDE
              + bad_cond.astype(i32) * GUARD_COND
              + bad_solve.astype(i32) * GUARD_BREAKDOWN)
    first = (gstate.first_trip < 0) & tripped
    step_i = jnp.asarray(step, i32)
    gstate = GuardState(
        env_r=jnp.where(jnp.isfinite(r_now),
                        jnp.minimum(gstate.env_r, r_now), gstate.env_r),
        env_g=jnp.where(jnp.isfinite(g_now),
                        jnp.minimum(gstate.env_g, g_now), gstate.env_g),
        trips=gstate.trips + tripped.astype(i32),
        first_trip=jnp.where(first, step_i, gstate.first_trip),
        first_reason=jnp.where(first, reason, gstate.first_reason),
        max_jitter=jnp.maximum(gstate.max_jitter, jitter))
    ginfo = {"guard_tripped": tripped.astype(dtype),
             "guard_reason": reason.astype(dtype),
             "guard_jitter": jitter}
    return dxs, gstate, ginfo


# --------------------------------------------------------------------------
# The one s-step body + driver
# --------------------------------------------------------------------------

def _assemble_subproblem(bound, G0, r, carry, flat, O, sb: int, scale=None):
    """Post-contraction subproblem assembly: ``A = scale*G0 + reg*(I or O)``
    plus the formulation rhs, from the RAW (unscaled, unregularized) Gram.

    This is deliberately the ONE code path both the single and the
    tenant-batched drivers run.  XLA's fma contraction is pattern-local and
    greedy: an identical mul/add graph contracts identically in any compiled
    body, but fusing the scale into the kernel for one driver and
    post-multiplying for the other gives the two drivers different graphs
    whose contraction choices differ -- an ulp apart on the regularized
    entries.  ``optimization_barrier`` does NOT block the contraction on the
    CPU backend (it happens below HLO), so identical graphs, not fences, are
    what keeps the drivers bit-for-bit.  ``O`` is the duplicate-index
    overlap matrix (diagonal exactly 1) or ``None`` for the local s_k=1
    step, whose only regularized entries are the diagonal.

    ``scale`` overrides ``bound.scale`` -- the batched driver passes a
    per-tenant TRACED scalar even when the value is tenant-independent
    (primal/proximal's 1/n).  A loop-invariant ``scale*G0`` gets hoisted
    out of the tenant ``lax.map`` loop, which parks the mul in a different
    basic block from the ``+ regO`` add and forfeits the fma the single
    driver's straight-line step performs -- the regularized diagonal lands
    an ulp apart.  A traced per-item scale pins the mul inside the loop
    next to the add, restoring the single driver's contraction."""
    dtype = G0.dtype
    # reg*O built as a SELECT on a barriered reg, not a multiply: O is a
    # 0/1 matrix, so the values are identical, but a mul here would compete
    # with scale*G0 for the fma contraction, and a python-float reg (single
    # driver) would constant-fold where a traced per-tenant reg (batched
    # driver) cannot -- either asymmetry leaves the two drivers' compiled
    # assemblies an ulp apart.  The barrier keeps reg a runtime value (that
    # much IS within optimization_barrier's power), so every driver carries
    # the same live select and scale*G0 is the only contractible mul.
    mask = jnp.eye(sb, dtype=bool) if O is None else O != 0
    regO = jnp.where(mask,
                     jax.lax.optimization_barrier(
                         jnp.asarray(bound.reg, dtype)),
                     jnp.zeros((), dtype))
    A = (bound.scale if scale is None else scale) * G0 + regO
    # The residual scale is applied HERE, not in the kernel epilogue: a
    # kernel-fused ``scale_r*acc`` sits in the same compiled body as the
    # formulation rhs and fma-contracts into it (single driver), while a
    # residual that crossed a loop or module boundary (batched driver, any
    # psum) arrives rounded -- an ulp apart on warm iterates.  With r raw
    # from the kernel, both drivers run this same mul-into-rhs seam and
    # contract identically.  scale_r is a static python float (1/n, or the
    # dual's exact 1.0, which folds), so no hoisting hazard arises: the mul
    # partner r is per-tenant/per-step either way.
    scale_r = bound.scale if bound.scale_r is None else bound.scale_r
    return A, bound.base(scale_r * r, carry, flat)


def _outer_step(bound: BoundFormulation, plan: SolverPlan, s_k: int, carry,
                idx_k, *, axis=None, collect=False, step=None, gstate=None,
                n_shards=1):
    """ONE outer iteration of the s-step method -- the repo's only solver hot
    loop.  ``s_k`` is the number of inner blocks this outer iteration carries
    (``plan.s`` normally; ``iters % s`` for the ragged tail).

    Every mode applies the regularizer post-contraction on the replicated
    (or local) Gram -- local adds ``reg*I`` at s_k=1 and ``reg*O`` with the
    duplicate-index overlap terms at s_k>1; distributed reduces the local
    contribution through :func:`_packet_reduce` first and then adds
    ``reg*O`` once on the replicated result.  Keeping reg OUT of the kernel
    keeps all paths (and the tenant-batched driver, whose per-tenant reg can
    never be fused into the one shared contraction) bit-for-bit consistent.

    Guard mode (``plan.guard``): the health word is computed on the local
    contribution (AFTER any injected fault, so injection is detectable),
    rides the one packet reduction, and the sweep runs through
    :func:`_guarded_sweep`.  ``step`` is the outer-iteration index (traced;
    only consumed by guards and fault hooks), ``gstate`` the
    :class:`GuardState` threaded across outer steps, ``n_shards`` the
    expected presence total.
    """
    b = plan.b
    sb = s_k * b
    pp = plan.packet
    dtype = bound.operand.dtype
    flat = idx_k.reshape(sb)
    dist = axis is not None
    u = bound.packet_vector(carry)
    # The packet leaves the kernel fully RAW (scale=1, scale_r=1, reg=0):
    # every scale and the regularizer are applied post-contraction by the
    # one shared :func:`_assemble_subproblem`, so the single and
    # tenant-batched drivers run the identical assembly graph (see that
    # helper for why identical graphs -- not fences -- are what keeps them
    # bit-for-bit, and why a kernel-fused scale_r in particular would
    # contract into the rhs here but not in the batched driver).
    Gl, rl = gram_packet_sampled(bound.operand, flat, u,
                                 scale=1.0, scale_r=1.0,
                                 reg=0.0, plan=pp)
    if plan.fault is not None:
        Gl, rl = plan.fault.apply_packet(Gl, rl, step=step, axis=axis)
    health = None
    if plan.guard:
        health = _health_local(Gl, rl, carry, u, dtype)
        if plan.fault is not None:
            health = plan.fault.apply_health(health, step=step, axis=axis)
    G, r, h = _packet_reduce(Gl, rl, axis, plan.fuse_packet, health)
    if dist or s_k > 1:
        O = overlap_matrix(flat).astype(dtype)             # shared-seed trick
    else:
        O = None        # a single block has no cross-block overlap terms
    A, base = _assemble_subproblem(bound, G, r, carry, flat, O, sb)
    if plan.guard:
        dxs, gstate, ginfo = _guarded_sweep(bound, plan, A, base, s_k, b,
                                            flat, carry, O, h, gstate, step,
                                            n_shards, dtype)
    else:
        dxs = bound.inner_sweep(A, base, s_k, b, flat, carry, O)
        ginfo = None

    if not collect:
        # Fast path (distributed): apply all s_k blocks in one deferred
        # update -- sum_j Y_j^T dx_j == Y^T dxs.
        return bound.update(carry, flat, dxs, pp), gstate, None

    # Metric path: reconstruct the per-inner-iteration trajectory locally.
    def inner(c, j):
        sl = jax.lax.dynamic_slice_in_dim
        c = bound.update(c, sl(flat, j * b, b), sl(dxs, j * b, b), pp)
        return c, bound.metrics(c)

    carry, hist = jax.lax.scan(inner, carry, jnp.arange(s_k))
    if plan.track_cond:
        # Fig. 4i conditions the scaled packet with its ridge diagonal
        # (scale*G + reg*I) -- the quantity the kernel used to emit when
        # scale/reg were fused.  The packet now leaves the kernel raw, so
        # rebuild it here; A is NOT it (A's off-diagonal overlap entries
        # shift the spectrum at s > 1).
        Greg = bound.scale * G + bound.reg * jnp.eye(sb, dtype=dtype)
        hist["gram_cond"] = jnp.full((s_k,), jnp.linalg.cond(Greg))
    if ginfo is not None:
        # Guard telemetry broadcast to the inner-iteration grid so it
        # concatenates with the other history series.
        for k, v in ginfo.items():
            hist[k] = jnp.full((s_k,), v)
    return carry, gstate, hist


def _gram_only(operand, flat, pp):
    """The Gram half of the packet for a FUTURE outer step.  ``u = 0`` /
    ``scale_r = 0`` make the fused residual output a don't-care, so this
    runs the same contraction cells as the fused packet's G (the batched
    driver's shared-Gram precedent) -- and, crucially, depends only on the
    index stream, never the solver carry, so the pipelined scan can contract
    step k+1's Gram while step k's reduction is on the wire."""
    u0 = jnp.zeros((operand.contraction,), operand.dtype)
    G, _ = gram_packet_sampled(operand, flat, u0, scale=1.0, scale_r=0.0,
                               reg=0.0, plan=pp)
    return G


def _outer_step_pipelined(bound: BoundFormulation, plan: SolverPlan, s_k: int,
                          carry, Gl, idx_k, flat_next, *, axis, axis_sizes,
                          step=None, gstate=None, n_shards=1):
    """ONE outer iteration on the pipelined wire (``plan.wire == "ring"``).

    ``Gl`` is THIS step's local Gram contribution, contracted one step ahead
    and double-buffered through the scan carry.  The body adds the
    carry-dependent half of the packet (the residual direction, which cannot
    be skewed), puts the whole packet -- Gram, residual, and in guard mode
    the health word, zero extra collectives -- on the decomposed ring
    reduction, and contracts the NEXT step's Gram between the ring's
    reduce-scatter and all-gather phases: the compute the monolithic psum
    would serialize behind the wire.  Fault hooks apply at consumption time,
    exactly where the psum backend applies them, so injection semantics (and
    the guard verdicts they trip) are identical across wires.
    """
    b = plan.b
    sb = s_k * b
    pp = plan.packet
    dtype = bound.operand.dtype
    flat = idx_k.reshape(sb)
    u = bound.packet_vector(carry)
    # Same contraction cells as the fused packet's r (the batched driver's
    # panel_matvec precedent); raw like every packet, scales applied by the
    # shared _assemble_subproblem.
    rl = panel_matvec(bound.operand, flat, u, scale=1.0, plan=pp)
    if plan.fault is not None:
        Gl, rl = plan.fault.apply_packet(Gl, rl, step=step, axis=axis)
    leaves = [Gl, rl]
    if plan.guard:
        health = _health_local(Gl, rl, carry, u, dtype)
        if plan.fault is not None:
            health = plan.fault.apply_health(health, step=step, axis=axis)
        leaves.append(health)
    red, Gl_next = ring_reduce_variadic(
        leaves, axis, axis_sizes,
        overlap_fn=lambda: _gram_only(bound.operand, flat_next, pp))
    G, r = red[0], red[1]
    h = red[2] if plan.guard else None
    O = overlap_matrix(flat).astype(dtype)
    A, base = _assemble_subproblem(bound, G, r, carry, flat, O, sb)
    if plan.guard:
        dxs, gstate, _ = _guarded_sweep(bound, plan, A, base, s_k, b, flat,
                                        carry, O, h, gstate, step, n_shards,
                                        dtype)
    else:
        dxs = bound.inner_sweep(A, base, s_k, b, flat, carry, O)
    return bound.update(carry, flat, dxs, pp), gstate, Gl_next


def _drive_pipelined(bound: BoundFormulation, plan: SolverPlan, idx, *, axis,
                     axis_sizes, n_shards=1, step0=0):
    """The software-pipelined s-step scan (``plan.wire == "ring"``): same
    outer/ragged split as :func:`_drive`, over :func:`_outer_step_pipelined`.

    The skew: the scan carry double-buffers the NEXT outer step's local Gram
    contribution.  A prologue contracts step 0's Gram before the scan; each
    step consumes the carried Gram, rides the ring, and contracts its
    successor's between the ring phases.  The epilogue cost is one discarded
    ``sb x sb`` contraction per scan segment (the last step's ``flat_next``
    is its own indices, standing in for a nonexistent step H+1) -- the
    standard software-pipelining prologue/epilogue shape.  The ragged tail's
    packet has a different width, so it runs its own prologue + length-1
    scan, like :func:`_drive`'s tail and for the same compiled-body reasons.

    History collection is not supported: the pipelined backend exists for
    the metric-free distributed fast path.  Returns ``(carry, {}, gstate)``.
    """
    s, b = plan.s, plan.b
    pp = plan.packet
    iters = idx.shape[0]
    outer_full, rem = divmod(iters, s)
    carry = bound.init_carry(axes=_axes(axis))
    gstate = _guard_init(bound.operand.dtype) if plan.guard else None
    if outer_full:
        blocks = idx[:outer_full * s].reshape(outer_full, s, b)
        flats = blocks.reshape(outer_full, s * b)
        flats_next = jnp.concatenate([flats[1:], flats[-1:]])
        Gl0 = _gram_only(bound.operand, flats[0], pp)

        def outer(cg, xs):
            step, idx_k, flat_next = xs
            c, g, Gl = _outer_step_pipelined(
                bound, plan, s, cg[0], cg[2], idx_k, flat_next, axis=axis,
                axis_sizes=axis_sizes, step=step, gstate=cg[1],
                n_shards=n_shards)
            return (c, g, Gl), None
        steps = jnp.arange(outer_full, dtype=jnp.int32) + step0
        (carry, gstate, _), _ = jax.lax.scan(
            outer, (carry, gstate, Gl0), (steps, blocks, flats_next),
            unroll=plan.unroll)
    if rem:
        flat_t = idx[outer_full * s:].reshape(rem * b)
        Gl_t = _gram_only(bound.operand, flat_t, pp)

        def tail(cg, xs):
            step, idx_k = xs
            c, g, Gl = _outer_step_pipelined(
                bound, plan, rem, cg[0], cg[2], idx_k, flat_t, axis=axis,
                axis_sizes=axis_sizes, step=step, gstate=cg[1],
                n_shards=n_shards)
            return (c, g, Gl), None
        (carry, gstate, _), _ = jax.lax.scan(
            tail, (carry, gstate, Gl_t),
            (jnp.asarray([outer_full + step0], jnp.int32),
             idx[outer_full * s:][None]))
    return carry, {}, gstate


def _resolve_form(formulation) -> "Formulation":
    """Resolve a formulation name (or pass an instance through), pulling in
    the sibling modules that self-register on first use."""
    if not isinstance(formulation, str):
        return formulation
    if formulation not in FORMULATIONS:
        from . import accelerated, bcd, bdcd, distributed, proximal  # noqa: F401
    try:
        return FORMULATIONS[formulation]
    except KeyError:
        raise KeyError(
            f"unknown formulation {formulation!r}; "
            f"available: {sorted(FORMULATIONS)}") from None


def _check_idx(idx, iters: int, b: int) -> None:
    """An explicit index stream must cover exactly the requested iterations
    (the pre-engine CA solvers raised on the mismatch via their reshape; keep
    that contract rather than silently running idx's length)."""
    if idx.shape != (iters, b):
        raise ValueError(
            f"idx shape {idx.shape} does not match (iters, b) = ({iters}, {b})")


def _drive(bound: BoundFormulation, plan: SolverPlan, idx, *, axis=None,
           collect=True, n_shards=1, step0=0, axis_sizes=None):
    """The engine's s-step scan: ``iters // s`` outer iterations through ONE
    ``lax.scan`` over :func:`_outer_step`, plus (when ``iters % s != 0``) a
    single ragged call of the same body with ``s_k = iters % s``.

    ``step0`` offsets the outer-iteration indices handed to the guard/fault
    hooks, so a segmented solve (the supervisor's checkpointed resume) keeps
    globally meaningful step numbers.  Returns ``(carry, history, gstate)``
    with ``gstate=None`` when guards are off.

    ``plan.wire == "ring"`` (distributed only; ``axis_sizes`` carries the
    static mesh axis sizes the ring needs) reroutes to the software-
    pipelined driver :func:`_drive_pipelined`.
    """
    if plan.wire == "ring" and axis is not None:
        return _drive_pipelined(bound, plan, idx, axis=axis,
                                axis_sizes=axis_sizes, n_shards=n_shards,
                                step0=step0)
    s, b = plan.s, plan.b
    iters = idx.shape[0]
    outer_full, rem = divmod(iters, s)
    carry = bound.init_carry(axes=None if axis is None else _axes(axis))
    gstate = _guard_init(bound.operand.dtype) if plan.guard else None
    hists = []
    if outer_full:
        def outer(cg, xs):
            step, idx_k = xs
            c, g, hist = _outer_step(bound, plan, s, cg[0], idx_k, axis=axis,
                                     collect=collect, step=step, gstate=cg[1],
                                     n_shards=n_shards)
            return (c, g), hist
        steps = jnp.arange(outer_full, dtype=jnp.int32) + step0
        (carry, gstate), hist = jax.lax.scan(
            outer, (carry, gstate),
            (steps, idx[:outer_full * s].reshape(outer_full, s, b)),
            unroll=plan.unroll)
        if collect:
            hists.append({k: v.reshape(outer_full * s, *v.shape[2:])
                          for k, v in hist.items()})
    if rem:
        # The ragged tail runs through a length-1 scan ON PURPOSE: lax.scan
        # compiles its body, so the tail sees the same compiled-body fma
        # contraction as the full steps and the batched driver's per-tenant
        # lax.map -- an eager tail would round the assembly seams
        # differently (see _assemble_subproblem).
        def tail(cg, xs):
            step, idx_k = xs
            c, g, hist = _outer_step(bound, plan, rem, cg[0], idx_k,
                                     axis=axis, collect=collect, step=step,
                                     gstate=cg[1], n_shards=n_shards)
            return (c, g), hist
        (carry, gstate), hist = jax.lax.scan(
            tail, (carry, gstate),
            (jnp.asarray([outer_full + step0], jnp.int32),
             idx[outer_full * s:][None]))
        if collect:
            hists.append({k: v.reshape(rem, *v.shape[2:])
                          for k, v in hist.items()})
    if len(hists) > 1:
        history = {k: jnp.concatenate([h[k] for h in hists]) for k in hists[0]}
    else:
        history = hists[0] if hists else {}
    return carry, history, gstate


def s_step_solve(formulation: Formulation | str, plan: SolverPlan,
                 X: jax.Array, y: jax.Array, lam: float, iters: int,
                 key: jax.Array | None = None, *, x0: jax.Array | None = None,
                 idx: jax.Array | None = None,
                 w_ref: jax.Array | None = None, step0: int = 0) -> SolveResult:
    """Single-device s-step solve.  ``plan.s == 1`` IS the classical variant;
    larger ``s`` trades bandwidth for latency without changing the iterates
    (the paper's central claim, preserved per-formulation by construction).

    ``x0`` warm-starts the formulation's own iterate (w for primal, alpha for
    dual).  ``idx`` overrides the sampled index stream -- the classical and
    CA runs that share it produce identical iterates in exact arithmetic.
    ``step0`` offsets the guard/fault outer-step numbering (segmented solves).

    With ``plan.guard`` the result's ``metrics`` carry the guard telemetry,
    and a trip at ``s > 1`` engages rung two of the degradation ladder: the
    clean prefix is replayed at ``s``, the remaining iterations run at
    ``s = 1`` so any further breakdown poisons one iteration instead of
    ``s`` (eager calls only -- under ``jit`` the ladder is skipped and the
    in-scan recovery of rung one is the whole story).
    """
    form = _resolve_form(formulation)
    if plan.wire != "psum":
        raise ValueError(
            f"SolverPlan.wire={plan.wire!r} needs a distributed backend; "
            "the local solve has no reduction to decompose")
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)
    bound = form.bind(X, y, lam, x0=x0, w_ref=w_ref)
    # Generic carry unpack: formulations may carry extra scan state beyond
    # (w, alpha) -- the accelerated formulation's velocity rides at [2:].
    carry, history, gstate = _drive(bound, plan, idx, step0=step0)
    w, alpha = carry[0], carry[1]
    metrics = {}
    if plan.guard:
        metrics = _guard_metrics(gstate)
        if plan.s > 1 and not isinstance(gstate.first_trip, jax.core.Tracer):
            first = int(jax.device_get(gstate.first_trip))
            if first >= 0:
                return _degrade_to_s1_tail(form, plan, X, y, lam, idx, first,
                                           step0, x0, w_ref, metrics)
    return SolveResult(w, alpha, history, metrics)


def _degrade_to_s1_tail(form, plan, X, y, lam, idx, first, step0, x0, w_ref,
                        metrics):
    """Degradation ladder, rung two (driver-level): a guard tripped at outer
    step ``first`` of an ``s > 1`` solve.  Replay the clean prefix at the
    original ``s`` (deterministic: the same index stream over the same data
    reproduces the same clean steps), warm-start from its iterate, and run
    the remaining iterations at ``s = 1`` -- further breakdowns now poison a
    single iteration's deferred update instead of ``s`` of them.  The tail
    keeps the guard (and any injected fault, remapped to fire at its outer
    step) so recovery is exercised, not dodged."""
    n_clean = (first - step0) * plan.s
    hists = []
    if n_clean > 0:
        pre = s_step_solve(form, plan, X, y, lam, n_clean, None, x0=x0,
                           idx=idx[:n_clean], w_ref=w_ref, step0=step0)
        hists.append(pre.history)
        x0 = pre.w if form.operand_layout == "rows" else pre.alpha
    tail_plan = dataclasses.replace(plan, s=1)
    tail = s_step_solve(form, tail_plan, X, y, lam, idx.shape[0] - n_clean,
                        None, x0=x0, idx=idx[n_clean:], w_ref=w_ref,
                        step0=first)
    if hists:
        history = {k: jnp.concatenate([h[k] for h in hists + [tail.history]])
                   for k in tail.history}
    else:
        history = tail.history
    metrics = dict(metrics)
    metrics["s1_tail_from_outer"] = first
    metrics["s1_tail_from_iter"] = n_clean
    metrics["s1_tail_trips"] = tail.metrics["guard_trips"]
    metrics["guard_max_jitter"] = jnp.maximum(
        metrics["guard_max_jitter"], tail.metrics["guard_max_jitter"])
    return SolveResult(tail.w, tail.alpha, history, metrics)


def s_step_solve_sharded(formulation: Formulation | str, plan: SolverPlan,
                         mesh: Mesh, X: jax.Array, y: jax.Array, lam: float,
                         iters: int, key: jax.Array | None = None, *,
                         axis="shards", idx: jax.Array | None = None,
                         x0: jax.Array | None = None, step0: int = 0):
    """Distributed s-step solve: the SAME driver as :func:`s_step_solve`,
    wrapped in ``shard_map`` with the formulation's 1D layout.  The only
    behavioural differences are the inserted packet all-reduce (one per outer
    iteration) and the skipped metric reconstruction.  Returns ``(w, alpha)``
    with the formulation's output sharding -- or ``(w, alpha, metrics)`` when
    ``plan.guard`` is set (the replicated guard telemetry, same keys as the
    local solve's ``SolveResult.metrics``).

    ``x0`` warm-starts the formulation's own replicated iterate (w for the
    primal family, alpha for the dual); the device-varying half of the carry
    is re-derived shard-locally (see the formulations' ``init_carry``), which
    is what the supervisor's checkpointed elastic restart rides.
    """
    form = _resolve_form(formulation)
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)
    axis_sizes = tuple(mesh.shape[a] for a in _axes(axis))
    n_shards = math.prod(axis_sizes)
    X, y = form.pad_shards(X, y, n_shards)
    has_x0 = x0 is not None

    def body(Xl, yl, idx_rep, *x0_rep):
        kw = {"x0": x0_rep[0]} if has_x0 else {}
        bound = form.bind_shard(Xl, yl, lam, d=d, n=n, **kw)
        carry, _, gstate = _drive(bound, plan, idx_rep, axis=axis,
                                  collect=False, n_shards=n_shards,
                                  step0=step0, axis_sizes=axis_sizes)
        return (carry, gstate) if plan.guard else carry

    in_specs = form.dist_in_specs(axis) + ((P(None),) if has_x0 else ())
    out_specs = form.dist_out_specs(axis)
    if plan.guard:
        out_specs = (out_specs, GuardState(*(P(),) * len(GuardState._fields)))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    args = (X, y, idx) + ((x0,) if has_x0 else ())
    # Generic carry unpack, like s_step_solve: extra carry leaves (the
    # accelerated velocity) ride at [2:] and are dropped by dist_finalize.
    if plan.guard:
        carry, gstate = fn(*args)
        w, alpha = form.dist_finalize(carry[0], carry[1], d, n)
        return w, alpha, _guard_metrics(gstate)
    carry = fn(*args)
    return form.dist_finalize(carry[0], carry[1], d, n)


# --------------------------------------------------------------------------
# Batched multi-tenant engine: one scan, one psum, T solves (DESIGN.md §8)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantBatch:
    """T tenant solves sharing ONE operand and ONE block-index stream.

    Production traffic is many small solves over the same dataset --
    personalization heads, a lambda path, CV folds -- so the batched engine
    carries a tenant axis through the s-step scan: the sb x sb Gram packet
    (and, sharded, its single psum) is computed once per outer step and
    reused by every tenant, while everything tenant-specific lives here.

    * ``ys`` (T, n): per-tenant targets (the dual's per-tenant Y row).
    * ``lams`` (T,): per-tenant l2 weights.
    * ``coeffs``: extra per-tenant bound-formulation fields, name ->
      (T,)-leading array (e.g. the proximal ``lam1``); applied by
      ``dataclasses.replace`` on the per-tenant bound under ``vmap``.
    * ``x0s`` (T, dim): optional per-tenant warm starts (the formulation's
      own iterate, like the single solve's ``x0``).
    * ``tol``: optional early-retirement threshold on the formulation's
      ``residual`` metric -- a tenant whose residual drops to ``tol`` or
      below has its subsequent updates masked to zero (frozen iterate,
      fixed compiled shapes).  Local backend only: the residual is carry
      state there, while sharded it would cost a second collective.
    """
    ys: jax.Array
    lams: jax.Array
    coeffs: dict = dataclasses.field(default_factory=dict)
    x0s: jax.Array | None = None
    tol: float | None = None

    def __post_init__(self):
        if self.ys.ndim != 2:
            raise ValueError(
                f"TenantBatch.ys must be (tenants, n), got {self.ys.shape}")
        T = self.ys.shape[0]
        if self.lams.shape != (T,):
            raise ValueError(
                f"TenantBatch.lams shape {self.lams.shape} != ({T},)")
        for name, v in self.coeffs.items():
            if v.shape[:1] != (T,):
                raise ValueError(
                    f"TenantBatch.coeffs[{name!r}] must lead with the "
                    f"tenant axis ({T},), got shape {v.shape}")
        if self.x0s is not None and self.x0s.shape[0] != T:
            raise ValueError(
                f"TenantBatch.x0s leads with {self.x0s.shape[0]} != {T}")
        if self.tol is not None and not self.tol > 0:
            raise ValueError(f"TenantBatch.tol={self.tol!r} must be > 0")

    @property
    def tenants(self) -> int:
        return self.ys.shape[0]


class BatchedSolveResult(NamedTuple):
    ws: jax.Array         # (T, d) per-tenant primal iterates
    alphas: jax.Array     # (T, n) per-tenant auxiliary iterates
    active: jax.Array     # (T,) bool: False once a tenant retired early
    metrics: dict = {}


@dataclasses.dataclass
class _BatchedSpec:
    """Everything the batched hot loop closes over: the shared operand and
    the per-tenant data.  The packet runs fully RAW (Gram scale 1, residual
    scale 1, reg 0) and every tenant applies its own scales through the
    shared :func:`_assemble_subproblem`, exactly like the single driver.
    ``scales`` carries the per-tenant Gram scale as a TRACED (T,) array
    when the formulation's scale is a tenant-independent python float --
    a loop-invariant ``scale*G0`` would be hoisted out of the tenant map
    and lose the single driver's fma (see :func:`_assemble_subproblem`);
    ``None`` means ``bound.scale`` is already per-tenant traced (the
    dual's pinned ``scale_c``) and is used directly."""
    form: object
    bind: Callable            # (y_t, lam_t, coeffs_t[, x0_t]) -> bound
    operand: PacketOperand
    ys: jax.Array
    lams: jax.Array
    coeffs: dict
    scales: jax.Array | None
    tol: float | None
    per_block: bool           # local per-block schedule vs one deferred update
    masked: bool              # thread/apply the active mask at all


def _pin_tenant_constants(form, batch: TenantBatch, d: int, n: int,
                          dtype) -> TenantBatch:
    """Pin host-exact derived constants (``Formulation.tenant_constants``)
    into ``batch.coeffs``.  A bound formulation built from a python-float
    lam computes its derived scalars (the dual's 1/(lam n^2) Gram scale and
    lam*n divisor) in f64 host arithmetic; a traced per-tenant lam would
    round each intermediate to f32 and land an ulp off the single solve.
    With concrete lams we replay the host arithmetic per tenant and ship the
    results as per-tenant coeffs; traced lams (jitted callers) fall back to
    in-graph arithmetic -- correct, just not bit-pinned."""
    tc = getattr(form, "tenant_constants", None)
    if tc is None:
        return batch
    try:
        lams = np.asarray(batch.lams)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return batch
    consts = [tc(float(lam), d, n) for lam in lams]
    extra = {k: jnp.asarray([c[k] for c in consts], dtype)
             for k in consts[0]}
    return dataclasses.replace(batch, coeffs={**batch.coeffs, **extra})


def _make_batched_spec(form, batch: TenantBatch, bind_one: Callable, *,
                       per_block: bool, masked: bool) -> _BatchedSpec:
    def bind(y_t, lam_t, coeffs_t, x0_t=None):
        bound = bind_one(y_t, lam_t, x0_t)
        return dataclasses.replace(bound, **coeffs_t) if coeffs_t else bound

    # Probe with an ARRAY-typed lam so tenant-dependent properties (the
    # dual's traced Gram scale) reveal themselves as non-floats.
    probe = bind(batch.ys[0], jnp.asarray(batch.lams[0]),
                 {k: v[0] for k, v in batch.coeffs.items()})
    scales = None
    if isinstance(probe.scale, (int, float)):
        # Tenant-independent Gram scale: ship it as a traced per-tenant
        # array anyway, or XLA hoists scale*G0 out of the tenant loop and
        # the assembly loses its fma (see _assemble_subproblem).
        scales = jnp.full((batch.tenants,), float(probe.scale),
                          batch.ys.dtype)
    return _BatchedSpec(
        form=form, bind=bind, operand=probe.operand, ys=batch.ys,
        lams=batch.lams, coeffs=batch.coeffs, scales=scales,
        tol=batch.tol, per_block=per_block, masked=masked)


def _init_batched(spec: _BatchedSpec, batch: TenantBatch, axes):
    # lax.map, not vmap: each tenant's init then lowers exactly like the
    # single solve's (warm starts included), keeping resumes bit-for-bit.
    if batch.x0s is None:
        def init(args):
            y_t, lam_t, coeffs_t = args
            return spec.bind(y_t, lam_t, coeffs_t).init_carry(axes=axes)
        return jax.lax.map(init, (batch.ys, batch.lams, batch.coeffs))

    def init(args):
        y_t, lam_t, coeffs_t, x0_t = args
        return spec.bind(y_t, lam_t, coeffs_t, x0_t).init_carry(axes=axes)
    return jax.lax.map(init, (batch.ys, batch.lams, batch.coeffs, batch.x0s))


def _outer_step_batched(spec: _BatchedSpec, plan: SolverPlan, s_k: int, state,
                        idx_k, *, axis=None, axis_sizes=None):
    """ONE batched outer iteration.  The sb x sb Gram contraction -- and, in
    distributed mode, its single psum -- happens ONCE and is reused by every
    tenant; only the per-tenant residual directions (T, sb) ride along, so
    the wire payload is sb^2 + T*sb words with the Gram part INDEPENDENT of
    T (the shared-packet invariant the analysis sweep pins down).

    Per-tenant math reproduces the single solve exactly: the regularizer is
    applied post-reduce per tenant (``(g + reg) + 0.0 == g + reg`` and
    ``reg * 1.0 == reg``, so the assembled subproblem matrix equals the
    single solve's under ``==``), the residual directions run through the
    SAME contraction cells as the fused packet's r, and the local schedule
    replays the single solve's per-block inner updates.
    """
    b = plan.b
    sb = s_k * b
    pp = plan.packet
    carries, active = state
    dtype = spec.operand.dtype
    flat = idx_k.reshape(sb)
    dist = axis is not None

    # Shared RAW Gram (scale=1, reg=0): both are per-tenant and are applied
    # by the same _assemble_subproblem the single driver runs, which is what
    # keeps the two drivers' assembly graphs -- and their fma contraction --
    # identical.  The fused residual output is a don't-care (u = 0,
    # scale_r = 0): every real residual is per-tenant.
    u0 = jnp.zeros((spec.operand.contraction,), dtype)
    G0, _ = gram_packet_sampled(spec.operand, flat, u0, scale=1.0,
                                scale_r=0.0, reg=0.0, plan=pp)

    def _direction(y_t, lam_t, coeffs_t, carry_t):
        # RAW direction (scale=1), like the single driver's raw packet r:
        # scale_r is applied by the shared _assemble_subproblem next to the
        # rhs seam it contracts into.
        u = spec.bind(y_t, lam_t, coeffs_t).packet_vector(carry_t)
        return panel_matvec(spec.operand, flat, u, scale=1.0, plan=pp)

    R = jax.vmap(_direction)(spec.ys, spec.lams, spec.coeffs, carries)

    if dist:
        # THE sync point, amortized across the tenant axis: one variadic
        # packet moving sb^2 + T*sb words per outer step.  On the ring wire
        # the shared Gram AND every tenant's direction ride the SAME
        # decomposed reduction -- zero extra collectives vs the psum wire,
        # just 2(P_i - 1) permute hops per axis instead of one all-reduce.
        if plan.wire == "ring":
            (G0, R), _ = ring_reduce_variadic([G0, R], axis, axis_sizes)
        else:
            G0, R = psum_variadic([G0, R], axis)

    if dist or s_k > 1:
        O = overlap_matrix(flat).astype(dtype)
    else:
        O = None            # a single block has no cross-block overlap terms

    # spec.scales is None when bound.scale is already per-tenant traced;
    # the dummy lams ride the map xs unused (DCE'd) to keep one structure.
    sc_xs = spec.lams if spec.scales is None else spec.scales

    def _sweep(args):
        y_t, lam_t, coeffs_t, r0_t, carry_t, sc_t = args
        bound = spec.bind(y_t, lam_t, coeffs_t)
        A, base = _assemble_subproblem(
            bound, G0, r0_t, carry_t, flat, O, sb,
            scale=None if spec.scales is None else sc_t)
        return bound.inner_sweep(A, base, s_k, b, flat, carry_t, O)

    # lax.map, NOT vmap: a batched Cholesky/triangular-solve lowers to a
    # different accumulation order than the unbatched one, so vmapping the
    # sweep would break bit-for-bit parity with the single solve (and the
    # barrier pins above have no vmap batching rule at all).  The per-tenant
    # assembly + sweep is O(s^2 b^2) -- noise next to the shared Gram -- so
    # sequencing it costs nothing while every tenant's subproblem runs
    # through the EXACT op sequence the single solve uses.
    dxs_all = jax.lax.map(
        _sweep, (spec.ys, spec.lams, spec.coeffs, R, carries, sc_xs))

    def _apply(args):
        y_t, lam_t, coeffs_t, dxs, carry_t, active_t = args
        bound = spec.bind(y_t, lam_t, coeffs_t)
        if spec.masked:
            # A retired tenant's applied update is zero: the carry freezes
            # while the compiled shapes (and the shared packet) stay put.
            dxs = jnp.where(active_t, dxs, jnp.zeros_like(dxs))
        if spec.per_block:
            # Replay the single local solve's per-block schedule so batched
            # iterates match unbatched ones bit-for-bit.
            def inner(c, j):
                sl = jax.lax.dynamic_slice_in_dim
                return bound.update(c, sl(flat, j * b, b),
                                    sl(dxs, j * b, b), pp), None
            carry_t, _ = jax.lax.scan(inner, carry_t, jnp.arange(s_k))
        else:
            carry_t = bound.update(carry_t, flat, dxs, pp)
        if spec.tol is not None:
            active_t = active_t & (bound.metrics(carry_t)["residual"]
                                   > spec.tol)
        return carry_t, active_t

    # lax.map again: the per-tenant update replays the single solve's exact
    # op sequence (scatter, panel apply, barrier-pinned epilogue) with
    # unbatched lowerings, which a vmap would not guarantee.
    carries, active = jax.lax.map(
        _apply, (spec.ys, spec.lams, spec.coeffs, dxs_all, carries, active))
    return carries, active


def _drive_batched(spec: _BatchedSpec, plan: SolverPlan, idx, state0, *,
                   axis=None, axis_sizes=None):
    """The batched s-step scan: same outer/ragged split as :func:`_drive`,
    over :func:`_outer_step_batched`."""
    s, b = plan.s, plan.b
    iters = idx.shape[0]
    outer_full, rem = divmod(iters, s)
    state = state0
    if outer_full:
        def outer(st, idx_k):
            return _outer_step_batched(spec, plan, s, st, idx_k, axis=axis,
                                       axis_sizes=axis_sizes), None
        state, _ = jax.lax.scan(
            outer, state, idx[:outer_full * s].reshape(outer_full, s, b),
            unroll=plan.unroll)
    if rem:
        # Length-1 scan for the same reason as _drive's tail: the single
        # driver's tail sees a compiled body with a TRACED index stream, and
        # an eager tail here would constant-fold the gathers and round the
        # per-tenant rhs seam differently (see _assemble_subproblem).
        def tail(st, idx_k):
            return _outer_step_batched(spec, plan, rem, st, idx_k, axis=axis,
                                       axis_sizes=axis_sizes), None
        state, _ = jax.lax.scan(tail, state, idx[outer_full * s:][None])
    return state


def _check_batched(form, plan: SolverPlan, batch: TenantBatch):
    if not getattr(form.contracts(), "tenant_batched", False):
        raise ValueError(
            f"formulation {form.name!r} does not declare tenant_batched "
            "support (SolverContracts.tenant_batched)")
    for knob in ("guard", "track_cond"):
        if getattr(plan, knob):
            raise ValueError(
                f"batched solves do not support SolverPlan.{knob} yet")
    if plan.fault is not None:
        raise ValueError("batched solves do not support SolverPlan.fault")
    if plan.tenants is not None and plan.tenants != batch.tenants:
        raise ValueError(
            f"SolverPlan.tenants={plan.tenants} != batch width "
            f"{batch.tenants}: a pinned plan is a compile-cache key, pad "
            "the batch to the bucket instead of recompiling")


def s_step_solve_batched(formulation: Formulation | str, plan: SolverPlan,
                         X: jax.Array, batch: TenantBatch, iters: int,
                         key: jax.Array | None = None, *,
                         idx: jax.Array | None = None, carry0=None,
                         active0: jax.Array | None = None
                         ) -> BatchedSolveResult:
    """Single-device batched solve: T tenants, ONE s-step scan, the Gram
    contraction shared.  Iterates equal T independent :func:`s_step_solve`
    runs over the same index stream -- bit-for-bit on matching kernel tiles
    (the dual's per-tenant Gram scale moves post-contraction, exact on the
    ref backend and on single-k-tile kernel launches; see DESIGN.md
    section 8).

    ``carry0`` (a ``(ws, alphas)`` pair) and ``active0`` resume a previous
    batched solve -- the serve front end steps solves in chunks and
    admits/retires tenants between chunks.  With ``batch.tol`` set, tenants
    whose ``residual`` metric reaches the tolerance are masked to no-ops
    for the rest of the solve (``result.active`` reports who was still
    running).  ``plan.guard`` / ``fault`` / ``track_cond`` are not
    supported on the batched path yet.
    """
    form = _resolve_form(formulation)
    _check_batched(form, plan, batch)
    if plan.wire != "psum":
        raise ValueError(
            f"SolverPlan.wire={plan.wire!r} needs a distributed backend; "
            "the local batched solve has no reduction to decompose")
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)

    def bind_one(y_t, lam_t, x0_t):
        kw = {"x0": x0_t} if x0_t is not None else {}
        return form.bind(X, y_t, lam_t, **kw)

    batch = _pin_tenant_constants(form, batch, d, n, X.dtype)
    masked = batch.tol is not None or active0 is not None
    spec = _make_batched_spec(form, batch, bind_one, per_block=True,
                              masked=masked)
    carries = _init_batched(spec, batch, None) if carry0 is None else carry0
    active = (jnp.ones((batch.tenants,), bool) if active0 is None
              else active0)
    (ws, alphas), active = _drive_batched(spec, plan, idx, (carries, active))
    return BatchedSolveResult(ws, alphas, active)


def batched_residuals(formulation: Formulation | str, X: jax.Array,
                      batch: TenantBatch, carries) -> jax.Array:
    """Per-tenant ``residual`` metric of a batched carry ``(ws, alphas)``.

    The serve front end thresholds this between solve chunks to retire
    tenants against their own tolerances (the engine's scalar
    ``TenantBatch.tol`` handles in-chunk masking; per-tenant tolerances are
    a host-side, chunk-granular decision).  Runs each tenant's metric
    through ``lax.map`` like the batched driver, so the statistic matches
    the single solve's bit-for-bit."""
    form = _resolve_form(formulation)
    d, n = X.shape

    def bind_one(y_t, lam_t, x0_t):
        return form.bind(X, y_t, lam_t)

    batch = _pin_tenant_constants(form, batch, d, n, X.dtype)
    spec = _make_batched_spec(form, batch, bind_one, per_block=True,
                              masked=False)

    def one(args):
        y_t, lam_t, coeffs_t, carry_t = args
        return spec.bind(y_t, lam_t, coeffs_t).metrics(carry_t)["residual"]

    return jax.lax.map(one, (spec.ys, spec.lams, spec.coeffs, carries))


def s_step_solve_batched_sharded(formulation: Formulation | str,
                                 plan: SolverPlan, mesh: Mesh, X: jax.Array,
                                 batch: TenantBatch, iters: int,
                                 key: jax.Array | None = None, *,
                                 axis="shards",
                                 idx: jax.Array | None = None
                                 ) -> BatchedSolveResult:
    """Distributed batched solve: the same batched driver under shard_map,
    with the ONE variadic psum per outer step now amortized across T
    tenants -- H = ceil(iters/s) all-reduces for the whole batch, payload
    sb^2 + T*sb words each, the Gram part independent of T (machine-checked
    by the analysis sweep at T in {1, 8, 64}).

    ``batch.tol`` is rejected here: the per-tenant residual is not carry
    state on a shard (the primal's alpha is sharded, the dual's metric
    needs the full X), so in-scan retirement would cost a SECOND collective
    -- the serve front end retires between chunks on the local backend
    instead.  ``result.active`` is therefore all-True.
    """
    form = _resolve_form(formulation)
    _check_batched(form, plan, batch)
    if batch.tol is not None:
        raise ValueError(
            "batched sharded solves do not support TenantBatch.tol: in-scan "
            "retirement would need a second collective per outer step; "
            "retire between chunks on the local backend instead")
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)
    axis_sizes = tuple(mesh.shape[a] for a in _axes(axis))
    n_shards = math.prod(axis_sizes)
    Xp, _ = form.pad_shards(X, batch.ys[0], n_shards)
    ysp = jax.vmap(lambda y: form.pad_shards(X, y, n_shards)[1])(batch.ys)
    # Pin host-exact derived constants while the lams are still concrete
    # (inside shard_map they are traced and the pin would be skipped).
    batch = _pin_tenant_constants(form, batch, d, n, X.dtype)
    has_x0 = batch.x0s is not None

    def body(Xl, ysl, lams, coeffs, idx_rep, *x0_rep):
        def bind_one(y_t, lam_t, x0_t):
            kw = {"x0": x0_t} if x0_t is not None else {}
            return form.bind_shard(Xl, y_t, lam_t, d=d, n=n, **kw)

        local = dataclasses.replace(
            batch, ys=ysl, lams=lams, coeffs=coeffs,
            x0s=x0_rep[0] if has_x0 else None)
        spec = _make_batched_spec(form, local, bind_one, per_block=False,
                                  masked=False)
        carries = _init_batched(spec, local, _axes(axis))
        active = jnp.ones((local.tenants,), bool)
        state = _drive_batched(spec, plan, idx_rep, (carries, active),
                               axis=axis, axis_sizes=axis_sizes)
        return state[0]

    def widen(p):
        # Prefix the tenant axis (replicated) onto a single-solve spec.
        return P(*((None,) + tuple(p)))

    xspec, yspec, repspec = form.dist_in_specs(axis)
    in_specs = (xspec, widen(yspec), P(None),
                jax.tree.map(lambda _: P(None), batch.coeffs), repspec)
    in_specs += ((P(None),) if has_x0 else ())
    wspec, aspec = form.dist_out_specs(axis)
    out_specs = (widen(wspec), widen(aspec))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    args = (Xp, ysp, batch.lams, batch.coeffs, idx)
    args += ((batch.x0s,) if has_x0 else ())
    ws, alphas = fn(*args)
    ws, alphas = jax.vmap(lambda w, a: form.dist_finalize(w, a, d, n))(
        ws, alphas)
    return BatchedSolveResult(ws, alphas, jnp.ones((batch.tenants,), bool))


# --------------------------------------------------------------------------
# Solver registry, keyed on (formulation, backend)
# --------------------------------------------------------------------------

BACKENDS = ("local", "sharded", "pipelined")
_REGISTRY: dict[tuple[str, str], Callable] = {}


def register_solver(formulation: str, backend: str, fn: Callable) -> Callable:
    """Register a solver entry point under ``(formulation, backend)``.  The
    four ridge entries are registered by ``repro.core.bcd`` / ``.bdcd`` /
    ``.distributed`` at import; new formulations add theirs next to their
    Formulation class.  ``pipelined`` entries share the sharded signature
    (mesh leading) and differ only in the wire schedule
    (``SolverPlan.wire == "ring"``, DESIGN.md section 9)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _REGISTRY[(formulation, backend)] = fn
    return fn


def get_solver(formulation: str, backend: str = "local") -> Callable:
    """Look up a solver.  ``local`` entries have the classical CA signature
    ``(X, y, lam, b, s, iters, key, **kw)``; ``sharded`` and ``pipelined``
    entries lead with the mesh: ``(mesh, X, y, lam, b, s, iters, key, **kw)``."""
    if (formulation, backend) not in _REGISTRY:
        # The built-in entries are registered by the sibling wrapper modules
        # at import; pull them in lazily so `from repro.core.engine import
        # get_solver` works without the package __init__ having run first.
        from . import accelerated, bcd, bdcd, distributed, proximal  # noqa: F401
    try:
        return _REGISTRY[(formulation, backend)]
    except KeyError:
        raise KeyError(
            f"no solver registered for ({formulation!r}, {backend!r}); "
            f"available: {sorted(_REGISTRY)}") from None


def registered_solvers() -> dict[tuple[str, str], Callable]:
    return dict(_REGISTRY)

"""The one s-step engine behind every (CA-)BCD / (CA-)BDCD variant.

The paper's communication-avoiding transform is a single algorithmic idea
(DESIGN.md section 5): sample ``s`` coordinate blocks up front, build ONE
``sb x sb`` Gram packet at the single communication point, then run ``s``
communication-free inner solves by block forward substitution.  Everything
that distinguishes the primal from the dual solver -- which operand's rows
are sampled, the packet's scale/regularizer, the subproblem right-hand side,
which iterate the deferred update touches -- is data, not control flow.  This
module therefore factors the repo's former six hand-rolled solver loops
(``bcd``/``ca_bcd``, ``bdcd``/``ca_bdcd``, and the two shard_map variants)
into

* a :class:`Formulation` (primal / dual): the handful of problem-specific
  hooks above, bound to concrete operands by ``bind`` / ``bind_shard`` --
  the operand is a :class:`~repro.kernels.gram.PacketOperand` (array +
  layout + gather strategy, DESIGN.md section 5.2), so "which axis is
  sampled and how" is the operand's business, not the engine's: the primal
  binds row-major X, the dual binds COLUMN-major X in its original (d, n)
  layout (no pre-transpose), and a pre-materialized kernel matrix binds
  through the same dispatch with zero engine edits;
* a :class:`SolverPlan`: the execution knobs (b, s, backend ``impl``, kernel
  ``tiles``, ``fuse_packet``, ``unroll``, ``track_cond``) -- ``s=1`` *is* the
  classical variant, not a separate loop;
* ONE driver, :func:`s_step_solve`, whose outer ``lax.scan`` body
  (:func:`_outer_step`) is the only s-step hot loop in the repo.  The
  distributed path (:func:`s_step_solve_sharded`) wraps the *same* driver in
  ``shard_map`` and flips exactly one switch: the packet regularizer moves
  out of the kernel and an all-reduce (:func:`_packet_reduce`) is inserted at
  the one communication point.

``iters`` need not be a multiple of ``s``: the driver runs ``iters // s`` full outer
iterations through the scan and, when ``iters % s != 0``, one ragged final
outer iteration through the same body with ``s_k = iters % s`` -- the CA
identity holds for any grouping of the index stream, so the iterates still
match the classical schedule bit-for-bit in exact arithmetic.

New formulations plug in by implementing the Formulation hooks and
registering under a name -- no new loop, no new shard_map.  The proximal
elastic-net methods of arXiv:1712.06047 are ``repro.core.proximal`` (the
first formulation added *through* the registry; its nonsmooth update rides
the ``inner_sweep`` hook); the kernel BDCD of arXiv:2406.18001 is the next
candidate.  The registry (:func:`register_solver` / :func:`get_solver`,
keyed on ``(formulation, backend)``) is how launch scripts, benchmarks, and
examples select solvers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.kernels.gram import (ColMajorOperand, PacketOperand, PacketPlan,
                                RowMajorOperand, gram_packet_sampled,
                                panel_apply)
from repro.kernels.gram.ops import _check_positive_int, _pad_axis

from .sampling import overlap_matrix, sample_blocks
from .subproblem import block_forward_substitution, choose_jitter


class SolveResult(NamedTuple):
    w: jax.Array          # (d,) primal iterate
    alpha: jax.Array      # (n,) auxiliary iterate (X^T w primal; dual vector)
    history: dict         # metric name -> (iters,) array (per inner iteration)
    metrics: dict = {}    # end-of-solve scalars (guard/recovery telemetry)


@dataclasses.dataclass(frozen=True)
class SolverContracts:
    """The communication/memory guarantees a formulation DECLARES -- and the
    static contract engine (``repro.analysis``) verifies against every
    registered lowering.

    The paper's headline result is a contract, not a number: CA-BCD/CA-BDCD
    synchronize exactly once per outer iteration (arXiv:1612.04003), the
    proximal variant inherits the same structure (arXiv:1712.06047), and the
    PR-2/PR-5 guarantees (panel never materializes; the dual binds the
    original layout with no transpose) are structural properties of the
    compiled HLO.  Each formulation states its invariants here instead of
    inheriting silent assumptions; ``python -m repro.analysis sweep`` lowers
    every ``(formulation, backend)`` registry entry and fails when a declared
    contract breaks.  A formulation without a ``contracts()`` hook FAILS the
    sweep -- declaring is mandatory, not optional.

    * ``sync_per_outer``: collectives per outer iteration on the sharded
      backend (1 for every paper formulation -- the single packet
      all-reduce).  A future pipelined-collective formulation would declare
      its own count here rather than silently widening the budget.
    * ``collective_kinds``: the only collective opcodes allowed to appear in
      the sharded lowering at all.
    * ``local_collective_free``: the local backend must lower with ZERO
      cross-device collectives.
    * ``operand_transpose_free``: no HLO transpose of the bound operand's
      (local) array anywhere in the sharded solve body -- the PR-5 "no dual
      pre-transpose" guarantee, checked shape-against-shape.
    * ``panel_free_impls``: kernel backends whose lowering must never
      materialize the sampled ``(sb, contraction)`` panel outside a Pallas
      custom-call (the ``impl="ref"`` path gathers the panel by design, so
      it is not listed).
    * ``f64_packet``: under the x64 test path every collective must move f64
      words (the packet may not silently downcast accumulation).
    * ``health_in_packet``: the formulation supports ``SolverPlan.guard``
      with the per-outer-step health word riding the ONE packet all-reduce
      (DESIGN.md section 7) -- the analysis engine additionally lowers the
      guard-enabled solver and asserts the collective count is UNCHANGED
      (exactly ``sync_per_outer * H``): the zero-extra-collectives guarantee.
    * ``lowering_kwargs``: extra solver kwargs ((key, value) pairs) the
      analysis engine passes when lowering this formulation abstractly, so
      formulation-specific code paths (e.g. the proximal soft-threshold at
      ``lam1 > 0``) are the ones verified.
    """
    sync_per_outer: int = 1
    collective_kinds: tuple = ("all-reduce",)
    local_collective_free: bool = True
    operand_transpose_free: bool = True
    panel_free_impls: tuple = ("pallas", "pallas_interpret")
    f64_packet: bool = True
    health_in_packet: bool = False
    lowering_kwargs: tuple = ()


@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """Everything the engine needs to know besides the problem data.

    ``b`` is the paper's block size (b' for the dual), ``s`` the loop-blocking
    parameter (s=1 recovers the classical algorithm).  ``impl``/``tiles``
    select the Gram-packet kernel backend and its (bm, bk) -- collapsed into
    one :class:`~repro.kernels.gram.PacketPlan` handed to every kernel call.
    ``fuse_packet`` picks the wire layout of the distributed reduction (see
    :func:`_packet_reduce`); ``unroll`` is forwarded to the outer scan;
    ``track_cond`` records cond(Gram) per outer iteration in the history.

    ``guard`` enables the in-scan health guards (DESIGN.md section 7): a
    per-outer-step health word rides the ONE packet reduction (zero extra
    collectives) and a tripped guard degrades the step -- adaptive diagonal
    jitter or a skipped update -- instead of corrupting ``s`` deferred
    iterations.  ``guard_boost`` is the divergence/magnitude envelope margin
    (trip when the tracked quantity exceeds ``boost x`` its running floor);
    ``guard_cond_max`` caps the Gram-diagonal ratio condition proxy (``None``
    picks ``0.1 / eps(dtype)``).  ``fault`` attaches a test-only
    :class:`repro.faults.FaultPlan` (duck-typed: anything with
    ``apply_packet`` / ``apply_health``) injected inside the hot loop.
    """
    b: int
    s: int = 1
    impl: str | None = None
    tiles: tuple[int, int] | None = None
    fuse_packet: bool = True
    unroll: int = 1
    track_cond: bool = False
    guard: bool = False
    guard_boost: float = 1e4
    guard_cond_max: float | None = None
    fault: object | None = None

    def __post_init__(self):
        # Fail fast at plan construction: a typo'd impl or a zero tile would
        # otherwise only surface at the first kernel call inside the jitted
        # scan (or, worse, silently fall through to the autotuned tiles).
        for name in ("b", "s", "unroll"):
            _check_positive_int(f"SolverPlan.{name}", getattr(self, name))
        if self.tiles is not None and len(self.tiles) != 2:
            raise ValueError(
                f"SolverPlan.tiles={self.tiles!r} must be a (bm, bk) pair")
        if not isinstance(self.guard, bool):
            raise ValueError(f"SolverPlan.guard={self.guard!r} must be a bool")
        if not self.guard_boost > 1:
            raise ValueError(
                f"SolverPlan.guard_boost={self.guard_boost!r} must be > 1")
        if self.guard_cond_max is not None and not self.guard_cond_max > 1:
            raise ValueError(
                f"SolverPlan.guard_cond_max={self.guard_cond_max!r} "
                "must be > 1 (or None for the dtype default)")
        if self.fault is not None and not (
                hasattr(self.fault, "apply_packet")
                and hasattr(self.fault, "apply_health")):
            raise ValueError(
                f"SolverPlan.fault={self.fault!r} must provide "
                "apply_packet/apply_health (see repro.faults.FaultPlan)")
        self.packet  # PacketPlan.make validates impl and the tile values

    @property
    def packet(self) -> PacketPlan:
        return PacketPlan.make(impl=self.impl, tiles=self.tiles)


@runtime_checkable
class BoundFormulation(Protocol):
    """A formulation bound to concrete operands (global or one shard's).

    ``operand`` is a :class:`~repro.kernels.gram.PacketOperand` -- the array
    plus its layout and gather strategy (DESIGN.md section 5.2).  The engine
    samples the operand's index space; the packet it builds is
    ``G = scale * Y Y^T + reg * I`` and ``r = scale_r * Y u`` for the
    operand's sampled panel ``Y(flat)`` (rows of the array for the primal's
    row-major operand, columns of the ORIGINAL layout for the dual's
    column-major operand, gathered pre-formed products for a materialized
    kernel matrix) and ``u = packet_vector(carry)``.  ``reg`` is also the
    coefficient of the duplicate-index overlap term, which is why a single
    scalar serves both the fused local diagonal and the post-reduce
    correction.

    ``inner_sweep`` owns the subproblem solve: given the replicated
    ``sb x sb`` system ``A`` and right-hand side ``base`` it returns the
    ``sb`` applied block updates.  The ridge formulations delegate to
    :func:`~repro.core.subproblem.block_forward_substitution`; nonsmooth
    formulations (the proximal elastic net) run the prox-aware variant --
    the hook exists precisely so a formulation can reshape each block's
    applied step without touching the engine's one hot-loop body.
    """
    operand: PacketOperand

    @property
    def scale(self) -> float: ...
    @property
    def scale_r(self) -> float | None: ...
    @property
    def reg(self) -> float: ...
    def init_carry(self, axes: tuple | None = None) -> tuple: ...
    def packet_vector(self, carry) -> jax.Array: ...
    def base(self, r: jax.Array, carry, flat: jax.Array) -> jax.Array: ...
    def inner_sweep(self, A: jax.Array, base: jax.Array, s_k: int, b: int,
                    flat: jax.Array, carry,
                    overlap: jax.Array | None) -> jax.Array: ...
    def update(self, carry, idx: jax.Array, dx: jax.Array,
               pp: PacketPlan) -> tuple: ...
    def metrics(self, carry) -> dict: ...


class Formulation(Protocol):
    """A problem formulation: how to bind data to a :class:`BoundFormulation`
    and how its operands shard (DESIGN.md section 5.3).  ``operand_layout``
    names the PacketOperand kind ``bind``/``bind_shard`` produce (DESIGN.md
    section 5.2) -- introspection only (dry-runs, benchmarks); the engine
    itself dispatches through the operand object."""
    name: str
    operand_layout: str

    def contracts(self) -> SolverContracts: ...
    def sample_dim(self, d: int, n: int) -> int: ...
    def bind(self, X, y, lam, *, x0=None, w_ref=None) -> BoundFormulation: ...
    def pad_shards(self, X, y, n_shards: int) -> tuple: ...
    def bind_shard(self, Xl, yl, lam, *, d: int, n: int,
                   x0=None) -> BoundFormulation: ...
    def dist_in_specs(self, axis) -> tuple: ...
    def dist_out_specs(self, axis) -> tuple: ...
    def dist_finalize(self, w, alpha, d: int, n: int) -> tuple: ...


# --------------------------------------------------------------------------
# Shared metric helpers
# --------------------------------------------------------------------------

def _objective_from_alpha(alpha, w, y, lam):
    # alpha == X^T w is maintained by the residual-form recurrence, so the
    # objective costs O(n + d) per iteration instead of O(dn).
    n = alpha.shape[0]
    r = alpha - y
    return 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)


def _sol_err(w, w_ref):
    return jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)


# --------------------------------------------------------------------------
# Primal formulation: min_w lam/2 ||w||^2 + 1/(2n) ||X^T w - y||^2
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BoundPrimal:
    """Algorithm 1/2 hooks; ``operand`` is the row-major X (d, n) or a column
    shard of it.

    Packet: Gamma = Y Y^T / n + lam I with Y = X[flat, :] and the residual
    contribution Y (y - alpha) / n of the Eq. (7)/(8) rhs; base subtracts the
    lam w term; the inner update is w[idx] += dw, alpha += Y_j^T dw (Eqs. 5,
    9-10).  All expressions are layout-neutral: on a column shard (y and
    alpha local, w replicated) they compute exactly the local contribution.
    """
    operand: PacketOperand
    y: jax.Array            # aligned with operand's columns
    lam: float
    n: int                  # GLOBAL data-point count (scales use it)
    d: int
    w0: jax.Array | None = None
    w_ref: jax.Array | None = None

    @property
    def scale(self):
        return 1.0 / self.n

    @property
    def scale_r(self):
        return None         # defaults to scale

    @property
    def reg(self):
        return self.lam

    def init_carry(self, axes=None):
        X = self.operand.array
        w = jnp.zeros((self.d,), X.dtype) if self.w0 is None else self.w0
        if axes is not None:
            # alpha is device-varying (each shard owns a slice of R^n); w is
            # replicated.  A warm-started w derives its local alpha slice as
            # ``w @ Xl`` -- no transpose, no gather -- which is what lets the
            # supervised restart path re-enter the sharded solve from a
            # checkpointed iterate (DESIGN.md section 7).
            if self.w0 is not None:
                return w, w @ X
            return w, compat.pvary(jnp.zeros(self.y.shape, X.dtype), axes)
        # contract: allow-transpose -- one-time warm-start init, not the
        # solve path (the hot loop's transpose-free-ness is what the HLO
        # contract pass pins; repro/analysis/lint.py enforces this comment).
        alpha = X.T @ w if self.w0 is not None else jnp.zeros((self.n,), X.dtype)
        return w, alpha

    def packet_vector(self, carry):
        return self.y - carry[1]

    def base(self, r, carry, flat):
        return r - self.lam * carry[0][flat]               # Eq. (7)/(8) rhs

    def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
        return block_forward_substitution(A, base, s_k, b)

    def update(self, carry, idx, dx, pp):
        w, alpha = carry
        w = w.at[idx].add(dx)                              # Eq. (9)
        alpha = alpha + panel_apply(self.operand, idx, dx, plan=pp)  # Eq. (5)/(10)
        return w, alpha

    def metrics(self, carry):
        w, alpha = carry
        m = {"objective": _objective_from_alpha(alpha, w, self.y, self.lam)}
        if self.w_ref is not None:
            m["sol_err"] = _sol_err(w, self.w_ref)
        return m


class PrimalRidge:
    """(CA-)BCD: samples features (rows of X); 1D-block-column layout."""
    name = "primal"
    operand_layout = "rows"

    def contracts(self):
        # Theorem 1/6 structure: ONE fused packet all-reduce per outer
        # iteration, nothing else on the wire; row-major operand, no
        # transpose, panel-free kernel path.  The health word rides that
        # same all-reduce (guard mode adds zero collectives).
        return SolverContracts(health_in_packet=True)

    def sample_dim(self, d, n):
        return d

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        d, n = X.shape
        return _BoundPrimal(operand=RowMajorOperand(X), y=y, lam=lam, n=n,
                            d=d, w0=x0, w_ref=w_ref)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 1), _pad_to(y, n_shards, 0)

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        return _BoundPrimal(operand=RowMajorOperand(Xl), y=yl, lam=lam, n=n,
                            d=d, w0=x0)

    def dist_in_specs(self, axis):
        return P(None, axis), P(axis), P(None)

    def dist_out_specs(self, axis):
        return P(None), P(axis)

    def dist_finalize(self, w, alpha, d, n):
        return w, alpha[:n]


# --------------------------------------------------------------------------
# Dual formulation: min_alpha lam/2 ||X alpha/(lam n)||^2 + 1/(2n) ||alpha + y||^2
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BoundDual:
    """Algorithm 3/4 hooks; ``operand`` is the column-major X (d, n) -- or a
    row shard Xl (dl, n) -- in its ORIGINAL layout.  The dual samples
    *columns* of X; the column-gather operand (``sampled_colmajor.py``) makes
    that a first-class access pattern, so no pre-transpose and no second
    resident copy of the dataset exist anywhere in the dual solve path
    (the PR-2..4 ``Xl.T`` workaround this replaces is discussed in
    ``repro.core.bdcd``).

    Packet: Theta = Y^T Y / (lam n^2) + I/n with Y = X[:, flat] plus the RAW
    projection Y^T w (scale_r=1); base assembles Eq. (17)/(18); the inner
    update is alpha[idx] += da, w -= Y_j da / (lam n) (Eqs. 15, 19-20).  On a
    row shard (w local, alpha and y replicated) the same expressions compute
    the local contribution.
    """
    operand: PacketOperand
    y: jax.Array            # (n,), replicated in the distributed layout
    lam: float
    n: int                  # GLOBAL data-point count
    X: jax.Array | None = None      # full X, for init + metrics (local mode)
    alpha0: jax.Array | None = None
    w_ref: jax.Array | None = None

    @property
    def scale(self):
        return 1.0 / (self.lam * self.n * self.n)

    @property
    def scale_r(self):
        return 1.0

    @property
    def reg(self):
        return 1.0 / self.n

    def init_carry(self, axes=None):
        dtype = self.operand.dtype
        if axes is not None:
            # w is device-varying (each shard owns a slice of R^d); alpha is
            # replicated.  The operand's contraction length IS the local dl.
            # A warm-started alpha derives its local w slice straight from
            # the ORIGINAL (dl, n) layout -- checkpointed restarts re-enter
            # the sharded solve transpose-free (DESIGN.md section 7).
            if self.alpha0 is not None:
                Xl = self.operand.array
                return -(Xl @ self.alpha0) / (self.lam * self.n), self.alpha0
            wl = compat.pvary(jnp.zeros((self.operand.contraction,), dtype),
                              axes)
            return wl, jnp.zeros((self.n,), dtype)
        alpha = jnp.zeros((self.n,), dtype) if self.alpha0 is None else self.alpha0
        w = -self.X @ alpha / (self.lam * self.n)
        return w, alpha

    def packet_vector(self, carry):
        return carry[0]

    def base(self, u, carry, flat):
        w, alpha = carry
        return (u - alpha[flat] - self.y[flat]) / self.n   # Eq. (17)/(18)

    def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
        return block_forward_substitution(A, base, s_k, b)

    def update(self, carry, idx, dx, pp):
        w, alpha = carry
        alpha = alpha.at[idx].add(dx)                      # Eq. (20)
        # Eq. (15)/(19): w -= X[:, idx] @ dx / (lam n) -- the column-major
        # operand's Y^T v, straight from the original layout.
        w = w - panel_apply(self.operand, idx, dx, plan=pp) / (self.lam * self.n)
        return w, alpha

    def metrics(self, carry):
        # Primal objective evaluated at the dual-generated primal iterate w:
        # X^T w is O(dn), affordable at the paper's figure sizes; the
        # distributed fast path skips metrics entirely.
        w, alpha = carry
        n = self.n
        # contract: allow-transpose -- metric evaluation on the full X
        # (local mode only; the distributed fast path skips metrics and the
        # HLO pass verifies its lowering is transpose-free).
        r = self.X.T @ w - self.y
        m = {"objective": 0.5 / n * (r @ r) + 0.5 * self.lam * (w @ w)}
        if self.w_ref is not None:
            m["sol_err"] = _sol_err(w, self.w_ref)
        return m


class DualRidge:
    """(CA-)BDCD: samples data points (columns of X) from the ORIGINAL
    (d, n) layout via the column-major operand; 1D-block-row layout."""
    name = "dual"
    operand_layout = "cols"

    def contracts(self):
        # Theorem 2/7 structure, plus the PR-5 guarantee this formulation
        # exists to keep: the ORIGINAL (d, n) layout is never transposed
        # anywhere in the sharded solve body.  Guard mode keeps both: the
        # health word rides the one packet all-reduce.
        return SolverContracts(health_in_packet=True)

    def sample_dim(self, d, n):
        return n

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        return _BoundDual(operand=ColMajorOperand(X), y=y, lam=lam,
                          n=X.shape[1], X=X, alpha0=x0, w_ref=w_ref)

    def pad_shards(self, X, y, n_shards):
        return _pad_to(X, n_shards, 0), y

    def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
        # The ORIGINAL (dl, n) shard, zero copies: the column-major operand
        # gathers sampled columns in place (pre-PR-5 this was ``Xl.T``,
        # doubling the resident dataset for the length of the solve).
        return _BoundDual(operand=ColMajorOperand(Xl), y=yl, lam=lam, n=n,
                          alpha0=x0)

    def dist_in_specs(self, axis):
        return P(axis, None), P(None), P(None)

    def dist_out_specs(self, axis):
        return P(axis), P(None)

    def dist_finalize(self, w, alpha, d, n):
        return w[:d], alpha


FORMULATIONS: dict[str, Formulation] = {
    "primal": PrimalRidge(),
    "dual": DualRidge(),
}


def register_formulation(form: Formulation) -> Formulation:
    """Publish a Formulation under its ``name`` so the string-keyed entry
    points (``s_step_solve(\"proximal\", ...)``, ``lower_solver``, the
    benchmark harness) can resolve it.  New formulations call this next to
    their ``register_solver`` entries (e.g. ``repro.core.proximal``)."""
    FORMULATIONS[form.name] = form
    return form


# --------------------------------------------------------------------------
# The communication point
# --------------------------------------------------------------------------

def _axes(axis) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


def psum_variadic(leaves, axis):
    """ONE all-reduce for any list of same-dtype arrays: ravel, concatenate,
    psum, split.  This is the explicit variadic packet: XLA builds without
    the all-reduce combiner would otherwise emit one op per array (the
    ROADMAP's 2-all-reduces-per-iteration artifact), which breaks the
    latency accounting the collective-count tests pin down."""
    shapes = [x.shape for x in leaves]
    flat = jnp.concatenate([x.ravel() for x in leaves])
    red = jax.lax.psum(flat, axis)
    out, off = [], 0
    for sh in shapes:
        size = math.prod(sh)
        out.append(red[off:off + size].reshape(sh))
        off += size
    return out


def _packet_reduce(G_local, r_local, axis, fuse, health=None):
    """THE sync point: one all-reduce per outer iteration, either as the
    fused sb x (sb+1) Gram||residual operand (``fuse_packet=True``, ours) or
    as the explicit variadic packet of the two separate operands
    (``fuse_packet=False``, the paper's two logical reductions packed into
    one wire message).

    Guard mode hands in the per-shard ``health`` word, which rides the SAME
    wire message through the variadic packet regardless of ``fuse`` -- the
    sharded health guards add ZERO extra collectives (the ``health_in_packet``
    contract, statically verified by the analysis sweep).  Returns
    ``(G, r, health)`` with ``health=None`` when no word was handed in.
    """
    if axis is None:
        return G_local, r_local, health
    if health is not None:
        G, r, h = psum_variadic([G_local, r_local, health], axis)
        return G, r, h
    if fuse:
        sb = G_local.shape[0]
        packet = jax.lax.psum(
            jnp.concatenate([G_local, r_local[:, None]], axis=1), axis)
        return packet[:, :sb], packet[:, sb], None
    G, r = psum_variadic([G_local, r_local], axis)
    return G, r, None


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to a multiple of ``mult``.  Zero rows/columns
    of X contribute nothing to Grams, residuals or updates, and the sampler
    only draws indices < the true size, so padding is exact (tested)."""
    return _pad_axis(x, mult, axis)


# --------------------------------------------------------------------------
# In-scan health guards (DESIGN.md section 7)
# --------------------------------------------------------------------------

# Guard-trip reason bits (``SolveResult.metrics["guard_first_reason"]``).
GUARD_NONFINITE = 1    # NaN/Inf in the packet or the solver carry
GUARD_SHARD_LOSS = 2   # a shard's presence flag missing from the reduction
GUARD_DIVERGENCE = 4   # packet-vector norm blew past its running envelope
GUARD_MAGNITUDE = 8    # packet magnitude blew past its envelope (bit flips)
GUARD_COND = 16        # Gram-diagonal condition proxy tripped
GUARD_BREAKDOWN = 32   # the inner sweep itself produced nonfinite updates

_HEALTH_WORDS = 5


class GuardState(NamedTuple):
    """Replicated guard telemetry threaded through the outer scan.  The
    envelopes are running minima of ``1 + ||u||^2`` / ``1 + max|G_local|``
    (the +1 floors them so an iterate growing from exactly zero -- the dual's
    cold-started w -- cannot arm a zero envelope); divergence/magnitude
    guards therefore need one clean outer step to arm."""
    env_r: jax.Array        # running floor of 1 + packet-vector norm^2
    env_g: jax.Array        # running floor of 1 + max |G_local|
    trips: jax.Array        # int32 count of tripped outer steps
    first_trip: jax.Array   # int32 outer index of the first trip (-1: clean)
    first_reason: jax.Array  # int32 GUARD_* bitmask at the first trip
    max_jitter: jax.Array   # largest diagonal jitter applied by a rescue


def _guard_init(dtype) -> GuardState:
    inf = jnp.asarray(jnp.inf, dtype)
    return GuardState(inf, inf, jnp.zeros((), jnp.int32),
                      jnp.full((), -1, jnp.int32), jnp.zeros((), jnp.int32),
                      jnp.zeros((), dtype))


def _guard_metrics(gstate: GuardState) -> dict:
    return {"guard_trips": gstate.trips,
            "guard_first_trip": gstate.first_trip,
            "guard_first_reason": gstate.first_reason,
            "guard_max_jitter": gstate.max_jitter}


def _health_local(Gl, rl, carry, u, dtype):
    """The per-shard health word (length ``_HEALTH_WORDS``) that rides the
    packet psum: [nonfinite count in (G, r); nonfinite count in the carry;
    local packet-vector squared norm; shard presence; max |G_local|].  All
    entries are sums, so ONE psum yields the global verdicts."""
    nonfinite = ((~jnp.isfinite(Gl)).sum()
                 + (~jnp.isfinite(rl)).sum()).astype(dtype)
    carry_bad = sum(((~jnp.isfinite(leaf)).sum()
                     for leaf in jax.tree.leaves(carry)),
                    jnp.zeros((), jnp.int32)).astype(dtype)
    r2 = jnp.sum(u * u).astype(dtype)
    present = jnp.ones((), dtype)
    gmax = jnp.max(jnp.abs(Gl)).astype(dtype)
    return jnp.stack([nonfinite, carry_bad, r2, present, gmax])


def _guarded_sweep(bound, plan, A, base, s_k, b, flat, carry, O, h, gstate,
                   step, n_shards, dtype):
    """Check the reduced health word, then solve -- degrading instead of
    corrupting.  Every decision derives from the replicated post-psum word
    (plus the replicated A / dxs), so all shards branch identically.

    The degradation ladder's first rung lives here: nonfinite packets,
    missing shards and bit-flip-scale magnitudes SKIP the update (dxs = 0 --
    one outer step of progress lost, carry untouched); divergence, the
    condition proxy and an inner-sweep breakdown RESCUE it (sanitize, pick
    the smallest working diagonal jitter, re-sweep).  Rung two (the s=1
    tail) is driver-level; rung three (restart) is the supervisor's.
    """
    i32 = jnp.int32
    boost = jnp.asarray(plan.guard_boost, dtype)
    one = jnp.asarray(1.0, dtype)
    bad_nonfinite = (h[0] + h[1]) > 0
    bad_shard = h[3] != n_shards
    r_now, g_now = one + h[2], one + h[4]
    bad_div = r_now > boost * gstate.env_r
    bad_mag = g_now > boost * gstate.env_g
    diag = jnp.diagonal(A)
    dmin = jnp.min(diag)
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    cond_max = (plan.guard_cond_max if plan.guard_cond_max is not None
                else 0.1 / float(jnp.finfo(dtype).eps))
    bad_cond = (dmin <= 0) | (
        jnp.max(diag) / jnp.maximum(dmin, tiny) > cond_max)
    skip = bad_nonfinite | bad_shard | bad_mag
    dxs = bound.inner_sweep(A, base, s_k, b, flat, carry, O)
    bad_solve = ~jnp.all(jnp.isfinite(dxs))
    rescue = (bad_div | bad_cond | bad_solve) & ~skip

    def _rescue(_):
        As = jnp.nan_to_num(A, nan=0.0, posinf=0.0, neginf=0.0)
        bs = jnp.nan_to_num(base, nan=0.0, posinf=0.0, neginf=0.0)
        jitter, _ok = choose_jitter(As)
        eye = jnp.eye(s_k * b, dtype=dtype)
        dj = bound.inner_sweep(As + jitter * eye, bs, s_k, b, flat, carry, O)
        return jnp.where(jnp.isfinite(dj), dj, jnp.zeros_like(dj)), jitter

    dxs, jitter = jax.lax.cond(
        rescue, _rescue, lambda _: (dxs, jnp.zeros((), dtype)), None)
    dxs = jnp.where(skip, jnp.zeros_like(dxs), dxs)
    tripped = skip | rescue
    reason = (bad_nonfinite.astype(i32) * GUARD_NONFINITE
              + bad_shard.astype(i32) * GUARD_SHARD_LOSS
              + bad_div.astype(i32) * GUARD_DIVERGENCE
              + bad_mag.astype(i32) * GUARD_MAGNITUDE
              + bad_cond.astype(i32) * GUARD_COND
              + bad_solve.astype(i32) * GUARD_BREAKDOWN)
    first = (gstate.first_trip < 0) & tripped
    step_i = jnp.asarray(step, i32)
    gstate = GuardState(
        env_r=jnp.where(jnp.isfinite(r_now),
                        jnp.minimum(gstate.env_r, r_now), gstate.env_r),
        env_g=jnp.where(jnp.isfinite(g_now),
                        jnp.minimum(gstate.env_g, g_now), gstate.env_g),
        trips=gstate.trips + tripped.astype(i32),
        first_trip=jnp.where(first, step_i, gstate.first_trip),
        first_reason=jnp.where(first, reason, gstate.first_reason),
        max_jitter=jnp.maximum(gstate.max_jitter, jitter))
    ginfo = {"guard_tripped": tripped.astype(dtype),
             "guard_reason": reason.astype(dtype),
             "guard_jitter": jitter}
    return dxs, gstate, ginfo


# --------------------------------------------------------------------------
# The one s-step body + driver
# --------------------------------------------------------------------------

def _outer_step(bound: BoundFormulation, plan: SolverPlan, s_k: int, carry,
                idx_k, *, axis=None, collect=False, step=None, gstate=None,
                n_shards=1):
    """ONE outer iteration of the s-step method -- the repo's only solver hot
    loop.  ``s_k`` is the number of inner blocks this outer iteration carries
    (``plan.s`` normally; ``iters % s`` for the ragged tail).

    Local mode (``axis=None``): the regularizer rides the kernel's fused
    diagonal and only the off-diagonal duplicate-index overlap terms are
    added (none exist at s_k=1, where the packet Gram IS the subproblem
    matrix).  Distributed mode: the local contribution is reduced by
    :func:`_packet_reduce` and the regularizer + full overlap are added once,
    after the psum, on the replicated result.

    Guard mode (``plan.guard``): the health word is computed on the local
    contribution (AFTER any injected fault, so injection is detectable),
    rides the one packet reduction, and the sweep runs through
    :func:`_guarded_sweep`.  ``step`` is the outer-iteration index (traced;
    only consumed by guards and fault hooks), ``gstate`` the
    :class:`GuardState` threaded across outer steps, ``n_shards`` the
    expected presence total.
    """
    b = plan.b
    sb = s_k * b
    pp = plan.packet
    dtype = bound.operand.dtype
    flat = idx_k.reshape(sb)
    dist = axis is not None
    u = bound.packet_vector(carry)
    Gl, rl = gram_packet_sampled(bound.operand, flat, u,
                                 scale=bound.scale, scale_r=bound.scale_r,
                                 reg=0.0 if dist else bound.reg, plan=pp)
    if plan.fault is not None:
        Gl, rl = plan.fault.apply_packet(Gl, rl, step=step, axis=axis)
    health = None
    if plan.guard:
        health = _health_local(Gl, rl, carry, u, dtype)
        if plan.fault is not None:
            health = plan.fault.apply_health(health, step=step, axis=axis)
    G, r, h = _packet_reduce(Gl, rl, axis, plan.fuse_packet, health)
    if dist:
        O = overlap_matrix(flat).astype(dtype)             # shared-seed trick
        A = G + bound.reg * O
    elif s_k == 1:
        O = None        # a single block has no cross-block overlap terms
        A = G
    else:
        O = overlap_matrix(flat).astype(dtype)
        # reg is already on G's diagonal; add only the off-diagonal
        # duplicate-index overlap terms (O's diagonal is exactly 1).
        A = G + bound.reg * (O - jnp.eye(sb, dtype=dtype))
    base = bound.base(r, carry, flat)
    if plan.guard:
        dxs, gstate, ginfo = _guarded_sweep(bound, plan, A, base, s_k, b,
                                            flat, carry, O, h, gstate, step,
                                            n_shards, dtype)
    else:
        dxs = bound.inner_sweep(A, base, s_k, b, flat, carry, O)
        ginfo = None

    if not collect:
        # Fast path (distributed): apply all s_k blocks in one deferred
        # update -- sum_j Y_j^T dx_j == Y^T dxs.
        return bound.update(carry, flat, dxs, pp), gstate, None

    # Metric path: reconstruct the per-inner-iteration trajectory locally.
    def inner(c, j):
        sl = jax.lax.dynamic_slice_in_dim
        c = bound.update(c, sl(flat, j * b, b), sl(dxs, j * b, b), pp)
        return c, bound.metrics(c)

    carry, hist = jax.lax.scan(inner, carry, jnp.arange(s_k))
    if plan.track_cond:
        # G already carries the regularized diagonal (local packet reg).
        hist["gram_cond"] = jnp.full((s_k,), jnp.linalg.cond(G))
    if ginfo is not None:
        # Guard telemetry broadcast to the inner-iteration grid so it
        # concatenates with the other history series.
        for k, v in ginfo.items():
            hist[k] = jnp.full((s_k,), v)
    return carry, gstate, hist


def _resolve_form(formulation) -> "Formulation":
    """Resolve a formulation name (or pass an instance through), pulling in
    the sibling modules that self-register on first use."""
    if not isinstance(formulation, str):
        return formulation
    if formulation not in FORMULATIONS:
        from . import bcd, bdcd, distributed, proximal  # noqa: F401
    try:
        return FORMULATIONS[formulation]
    except KeyError:
        raise KeyError(
            f"unknown formulation {formulation!r}; "
            f"available: {sorted(FORMULATIONS)}") from None


def _check_idx(idx, iters: int, b: int) -> None:
    """An explicit index stream must cover exactly the requested iterations
    (the pre-engine CA solvers raised on the mismatch via their reshape; keep
    that contract rather than silently running idx's length)."""
    if idx.shape != (iters, b):
        raise ValueError(
            f"idx shape {idx.shape} does not match (iters, b) = ({iters}, {b})")


def _drive(bound: BoundFormulation, plan: SolverPlan, idx, *, axis=None,
           collect=True, n_shards=1, step0=0):
    """The engine's s-step scan: ``iters // s`` outer iterations through ONE
    ``lax.scan`` over :func:`_outer_step`, plus (when ``iters % s != 0``) a
    single ragged call of the same body with ``s_k = iters % s``.

    ``step0`` offsets the outer-iteration indices handed to the guard/fault
    hooks, so a segmented solve (the supervisor's checkpointed resume) keeps
    globally meaningful step numbers.  Returns ``(carry, history, gstate)``
    with ``gstate=None`` when guards are off.
    """
    s, b = plan.s, plan.b
    iters = idx.shape[0]
    outer_full, rem = divmod(iters, s)
    carry = bound.init_carry(axes=None if axis is None else _axes(axis))
    gstate = _guard_init(bound.operand.dtype) if plan.guard else None
    hists = []
    if outer_full:
        def outer(cg, xs):
            step, idx_k = xs
            c, g, hist = _outer_step(bound, plan, s, cg[0], idx_k, axis=axis,
                                     collect=collect, step=step, gstate=cg[1],
                                     n_shards=n_shards)
            return (c, g), hist
        steps = jnp.arange(outer_full, dtype=jnp.int32) + step0
        (carry, gstate), hist = jax.lax.scan(
            outer, (carry, gstate),
            (steps, idx[:outer_full * s].reshape(outer_full, s, b)),
            unroll=plan.unroll)
        if collect:
            hists.append({k: v.reshape(outer_full * s, *v.shape[2:])
                          for k, v in hist.items()})
    if rem:
        carry, gstate, hist = _outer_step(
            bound, plan, rem, carry, idx[outer_full * s:], axis=axis,
            collect=collect, step=jnp.asarray(outer_full + step0, jnp.int32),
            gstate=gstate, n_shards=n_shards)
        if collect:
            hists.append(hist)
    if len(hists) > 1:
        history = {k: jnp.concatenate([h[k] for h in hists]) for k in hists[0]}
    else:
        history = hists[0] if hists else {}
    return carry, history, gstate


def s_step_solve(formulation: Formulation | str, plan: SolverPlan,
                 X: jax.Array, y: jax.Array, lam: float, iters: int,
                 key: jax.Array | None = None, *, x0: jax.Array | None = None,
                 idx: jax.Array | None = None,
                 w_ref: jax.Array | None = None, step0: int = 0) -> SolveResult:
    """Single-device s-step solve.  ``plan.s == 1`` IS the classical variant;
    larger ``s`` trades bandwidth for latency without changing the iterates
    (the paper's central claim, preserved per-formulation by construction).

    ``x0`` warm-starts the formulation's own iterate (w for primal, alpha for
    dual).  ``idx`` overrides the sampled index stream -- the classical and
    CA runs that share it produce identical iterates in exact arithmetic.
    ``step0`` offsets the guard/fault outer-step numbering (segmented solves).

    With ``plan.guard`` the result's ``metrics`` carry the guard telemetry,
    and a trip at ``s > 1`` engages rung two of the degradation ladder: the
    clean prefix is replayed at ``s``, the remaining iterations run at
    ``s = 1`` so any further breakdown poisons one iteration instead of
    ``s`` (eager calls only -- under ``jit`` the ladder is skipped and the
    in-scan recovery of rung one is the whole story).
    """
    form = _resolve_form(formulation)
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)
    bound = form.bind(X, y, lam, x0=x0, w_ref=w_ref)
    (w, alpha), history, gstate = _drive(bound, plan, idx, step0=step0)
    metrics = {}
    if plan.guard:
        metrics = _guard_metrics(gstate)
        if plan.s > 1 and not isinstance(gstate.first_trip, jax.core.Tracer):
            first = int(jax.device_get(gstate.first_trip))
            if first >= 0:
                return _degrade_to_s1_tail(form, plan, X, y, lam, idx, first,
                                           step0, x0, w_ref, metrics)
    return SolveResult(w, alpha, history, metrics)


def _degrade_to_s1_tail(form, plan, X, y, lam, idx, first, step0, x0, w_ref,
                        metrics):
    """Degradation ladder, rung two (driver-level): a guard tripped at outer
    step ``first`` of an ``s > 1`` solve.  Replay the clean prefix at the
    original ``s`` (deterministic: the same index stream over the same data
    reproduces the same clean steps), warm-start from its iterate, and run
    the remaining iterations at ``s = 1`` -- further breakdowns now poison a
    single iteration's deferred update instead of ``s`` of them.  The tail
    keeps the guard (and any injected fault, remapped to fire at its outer
    step) so recovery is exercised, not dodged."""
    n_clean = (first - step0) * plan.s
    hists = []
    if n_clean > 0:
        pre = s_step_solve(form, plan, X, y, lam, n_clean, None, x0=x0,
                           idx=idx[:n_clean], w_ref=w_ref, step0=step0)
        hists.append(pre.history)
        x0 = pre.w if form.operand_layout == "rows" else pre.alpha
    tail_plan = dataclasses.replace(plan, s=1)
    tail = s_step_solve(form, tail_plan, X, y, lam, idx.shape[0] - n_clean,
                        None, x0=x0, idx=idx[n_clean:], w_ref=w_ref,
                        step0=first)
    if hists:
        history = {k: jnp.concatenate([h[k] for h in hists + [tail.history]])
                   for k in tail.history}
    else:
        history = tail.history
    metrics = dict(metrics)
    metrics["s1_tail_from_outer"] = first
    metrics["s1_tail_from_iter"] = n_clean
    metrics["s1_tail_trips"] = tail.metrics["guard_trips"]
    metrics["guard_max_jitter"] = jnp.maximum(
        metrics["guard_max_jitter"], tail.metrics["guard_max_jitter"])
    return SolveResult(tail.w, tail.alpha, history, metrics)


def s_step_solve_sharded(formulation: Formulation | str, plan: SolverPlan,
                         mesh: Mesh, X: jax.Array, y: jax.Array, lam: float,
                         iters: int, key: jax.Array | None = None, *,
                         axis="shards", idx: jax.Array | None = None,
                         x0: jax.Array | None = None, step0: int = 0):
    """Distributed s-step solve: the SAME driver as :func:`s_step_solve`,
    wrapped in ``shard_map`` with the formulation's 1D layout.  The only
    behavioural differences are the inserted packet all-reduce (one per outer
    iteration) and the skipped metric reconstruction.  Returns ``(w, alpha)``
    with the formulation's output sharding -- or ``(w, alpha, metrics)`` when
    ``plan.guard`` is set (the replicated guard telemetry, same keys as the
    local solve's ``SolveResult.metrics``).

    ``x0`` warm-starts the formulation's own replicated iterate (w for the
    primal family, alpha for the dual); the device-varying half of the carry
    is re-derived shard-locally (see the formulations' ``init_carry``), which
    is what the supervisor's checkpointed elastic restart rides.
    """
    form = _resolve_form(formulation)
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), plan.b, iters)
    else:
        _check_idx(idx, iters, plan.b)
    n_shards = math.prod(mesh.shape[a] for a in _axes(axis))
    X, y = form.pad_shards(X, y, n_shards)
    has_x0 = x0 is not None

    def body(Xl, yl, idx_rep, *x0_rep):
        kw = {"x0": x0_rep[0]} if has_x0 else {}
        bound = form.bind_shard(Xl, yl, lam, d=d, n=n, **kw)
        carry, _, gstate = _drive(bound, plan, idx_rep, axis=axis,
                                  collect=False, n_shards=n_shards,
                                  step0=step0)
        return (carry, gstate) if plan.guard else carry

    in_specs = form.dist_in_specs(axis) + ((P(None),) if has_x0 else ())
    out_specs = form.dist_out_specs(axis)
    if plan.guard:
        out_specs = (out_specs, GuardState(*(P(),) * len(GuardState._fields)))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
    args = (X, y, idx) + ((x0,) if has_x0 else ())
    if plan.guard:
        (w, alpha), gstate = fn(*args)
        w, alpha = form.dist_finalize(w, alpha, d, n)
        return w, alpha, _guard_metrics(gstate)
    w, alpha = fn(*args)
    return form.dist_finalize(w, alpha, d, n)


# --------------------------------------------------------------------------
# Solver registry, keyed on (formulation, backend)
# --------------------------------------------------------------------------

BACKENDS = ("local", "sharded")
_REGISTRY: dict[tuple[str, str], Callable] = {}


def register_solver(formulation: str, backend: str, fn: Callable) -> Callable:
    """Register a solver entry point under ``(formulation, backend)``.  The
    four ridge entries are registered by ``repro.core.bcd`` / ``.bdcd`` /
    ``.distributed`` at import; new formulations add theirs next to their
    Formulation class."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _REGISTRY[(formulation, backend)] = fn
    return fn


def get_solver(formulation: str, backend: str = "local") -> Callable:
    """Look up a solver.  ``local`` entries have the classical CA signature
    ``(X, y, lam, b, s, iters, key, **kw)``; ``sharded`` entries lead with the
    mesh: ``(mesh, X, y, lam, b, s, iters, key, **kw)``."""
    if (formulation, backend) not in _REGISTRY:
        # The built-in entries are registered by the sibling wrapper modules
        # at import; pull them in lazily so `from repro.core.engine import
        # get_solver` works without the package __init__ having run first.
        from . import bcd, bdcd, distributed, proximal  # noqa: F401
    try:
        return _REGISTRY[(formulation, backend)]
    except KeyError:
        raise KeyError(
            f"no solver registered for ({formulation!r}, {backend!r}); "
            f"available: {sorted(_REGISTRY)}") from None


def registered_solvers() -> dict[tuple[str, str], Callable]:
    return dict(_REGISTRY)

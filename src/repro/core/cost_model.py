"""The paper's alpha-beta-gamma running-time model (Eq. 1, Tables 1-2) plus the
modeled strong/weak scaling experiments of Figures 8-9, extended with TPU-pod
machine models (DESIGN.md section 2).

T = gamma * F + alpha * L + beta * W

with per-algorithm critical-path costs.  Leading constants follow the proofs of
Theorems 1/2/6/7 (Gram + residual + subproblem + vector updates); Big-O
constants the paper drops are kept as explicit small integers so the modeled
curves are reproducible, and dropping them shifts all curves proportionally
(paper footnote 3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    gamma: float   # seconds per flop
    alpha: float   # seconds per message
    beta: float    # seconds per word moved


# NERSC Cori constants from the paper (section 5.2, ref [1]); Spark raises the
# effective latency to 1e-3 s per reduction (scheduling/centralization, ref [20]).
CORI_MPI = MachineModel("cori-mpi", gamma=8e-13, alpha=1e-6, beta=1.3e-10)
CORI_SPARK = MachineModel("cori-spark", gamma=8e-13, alpha=1e-3, beta=1.3e-10)

# TPU v5e adaptation (hardware constants from the assignment): 197 TFLOP/s bf16
# per chip, ~50 GB/s/link ICI, ~1 us collective launch.  Words are 4 bytes to
# stay commensurate with the paper's model.  The DCN (inter-pod) model carries
# the Spark-like latency penalty: O(100 us) software-driven reductions.
TPU_V5E_ICI = MachineModel("tpu-v5e-ici", gamma=1 / 197e12, alpha=1e-6, beta=4 / 50e9)
TPU_V5E_DCN = MachineModel("tpu-v5e-dcn", gamma=1 / 197e12, alpha=1e-4, beta=4 / 2.5e9)

MACHINES = {m.name: m for m in (CORI_MPI, CORI_SPARK, TPU_V5E_ICI, TPU_V5E_DCN)}


@dataclasses.dataclass(frozen=True)
class Costs:
    flops: float      # F
    latency: float    # L (number of messages)
    bandwidth: float  # W (words moved)
    memory: float     # M (words per processor)

    def time(self, m: MachineModel) -> float:
        return m.gamma * self.flops + m.alpha * self.latency + m.beta * self.bandwidth


def _logp(P: float) -> float:
    return max(math.log2(max(P, 2)), 1.0)


def bcd_costs(d: int, n: int, P: int, b: int, H: int, s: int = 1) -> Costs:
    """Theorem 1 (s=1) / Theorem 6 (s>1), 1D-block-column layout.

    Per outer iteration (every s inner iterations): one (sb x sb) Gram
    all-reduce fused with the residual, s local b x b Cholesky solves, local
    vector updates.
    """
    outer = H / s
    sb = s * b
    gram_flops = sb * sb * n / P + sb * n / P          # Y Y^T + residual panel
    solve_flops = s * (b ** 3 / 3 + 2 * b * b) + sb * sb * s  # chol + subst + corrections
    update_flops = sb + sb * n / P                     # w and alpha updates
    F = outer * (gram_flops + solve_flops + update_flops)
    L = outer * 2 * _logp(P)                           # one fused all-reduce (tree up+down)
    W = outer * (sb * sb + sb) * _logp(P)
    M = d * n / P + sb * sb + 2 * sb + d + 2 * n / P
    return Costs(F, L, W, M)


def bdcd_costs(d: int, n: int, P: int, b: int, H: int, s: int = 1) -> Costs:
    """Theorem 2 (s=1) / Theorem 7 (s>1), 1D-block-row layout; b is b'."""
    outer = H / s
    sb = s * b
    gram_flops = sb * sb * d / P + sb * d / P
    solve_flops = s * (b ** 3 / 3 + 2 * b * b) + sb * sb * s
    update_flops = sb + sb * d / P
    F = outer * (gram_flops + solve_flops + update_flops)
    L = outer * 2 * _logp(P)
    W = outer * (sb * sb + sb) * _logp(P)
    M = d * n / P + sb * sb + 2 * sb + n + 2 * d / P
    return Costs(F, L, W, M)


def snapshot_cadence(machine: MachineModel, *, d: int, n: int, P: int, b: int,
                     s: int, mtbf_outer: float, formulation: str = "primal",
                     ) -> dict:
    """Young's rule for the supervisor's snapshot interval, in OUTER steps.

    The solver carry snapshot is the logical iterate pair (w in R^d, alpha in
    R^n) -- ``d + n`` words gathered and written once, modeled as one message
    (``t_snap = alpha + beta (d + n)``).  One outer step costs the
    formulation's Theorem 6/7 critical path at H = s (``t_step``).  With
    failures arriving every ``mtbf_outer`` outer steps on average, the
    classical first-order optimum balances snapshot overhead ``t_snap / k``
    against expected replay ``k t_step / (2 mtbf)``:

        k* = sqrt(2 * mtbf_outer * t_snap / t_step)

    Returns ``{"cadence", "t_snap", "t_step", "overhead"}`` -- cadence is
    k* clamped to >= 1, overhead the per-step fraction
    ``t_snap / (k* t_step) + k* t_step / (2 mtbf t_step)`` the supervisor
    pays for resilience (DESIGN.md section 7 carries the worked example).
    """
    if mtbf_outer <= 0:
        raise ValueError(f"mtbf_outer={mtbf_outer} must be > 0")
    t_snap = machine.alpha + machine.beta * (d + n)
    cost_fn = bdcd_costs if formulation == "dual" else bcd_costs
    t_step = cost_fn(d, n, P, b, s, s).time(machine)
    k = max(1, round(math.sqrt(2 * mtbf_outer * t_snap / t_step)))
    overhead = t_snap / (k * t_step) + k / (2 * mtbf_outer)
    return {"cadence": k, "t_snap": t_snap, "t_step": t_step,
            "overhead": overhead}


def cg_costs(d: int, n: int, P: int, k: int) -> Costs:
    """Krylov row of Table 2: 1D layout, small-dimension vectors replicated."""
    F = k * (4 * d * n / P + 5 * min(d, n))
    L = k * 2 * _logp(P)
    W = k * min(d, n) * _logp(P)
    M = d * n / P + 4 * min(d, n)
    return Costs(F, L, W, M)


def tsqr_costs(d: int, n: int, P: int) -> Costs:
    """TSQR row of Table 2: single reduction over local R factors."""
    c, r = min(d, n), max(d, n)
    F = 2 * c * c * r / P + (2 * c ** 3 / 3) * _logp(P)
    L = _logp(P)
    W = c * c / 2 * _logp(P)
    M = d * n / P + c * c
    return Costs(F, L, W, M)


ALGORITHMS: dict[str, Callable[..., Costs]] = {
    "bcd": bcd_costs, "bdcd": bdcd_costs,
}


# --------------------------------------------------------------------------
# Batched multi-tenant solves (DESIGN.md section 8)
# --------------------------------------------------------------------------
# T tenant solves share ONE operand, ONE block-index stream, and therefore
# ONE sb x sb Gram contraction and ONE psum per outer step; only the (T, sb)
# residual directions, the T subproblem sweeps, and the T vector updates
# scale with the tenant axis.  The sync term (alpha * L) is PER BATCH, not
# per tenant -- that amortization is the whole point of the tenant axis, and
# it is what the solves/s model below exposes: on latency-dominated machines
# throughput grows ~linearly in T until the per-tenant flop/bandwidth terms
# take over.

def batched_costs(d: int, n: int, P: int, b: int, H: int, s: int = 1,
                  tenants: int = 1, formulation: str = "primal") -> Costs:
    """Critical-path costs of ONE T-tenant batched solve of H iterations.

    Shared per outer step: the sb x sb Gram contraction and the (single)
    all-reduce.  Per tenant per outer step: the residual direction, the s
    small Cholesky solves, and the iterate updates -- Theorem 6/7 terms with
    the Gram row paid once.  Wire: sb^2 + T*sb words per outer step (the
    contract the analysis sweep machine-checks).  Memory: the shared operand
    shard plus T iterate/target stripes.
    """
    if tenants < 1:
        raise ValueError(f"tenants={tenants} must be >= 1")
    outer = H / s
    sb = s * b
    c = n if formulation != "dual" else d      # local contraction length
    gram_flops = sb * sb * c / P               # shared: ONE Y Y^T per step
    per_tenant = (sb * c / P                               # residual panel
                  + s * (b ** 3 / 3 + 2 * b * b) + sb * sb * s  # subproblem
                  + sb + sb * c / P)                       # updates
    F = outer * (gram_flops + tenants * per_tenant)
    L = outer * 2 * _logp(P)                   # ONE fused all-reduce, any T
    W = outer * (sb * sb + tenants * sb) * _logp(P)
    other = d if formulation != "dual" else n  # replicated iterate length
    M = d * n / P + sb * sb + tenants * (2 * sb + other + 2 * c / P)
    return Costs(F, L, W, M)


def tenant_bytes_per_iter(d: int, n: int, P: int, b: int, s: int,
                          tenants: int, formulation: str = "primal",
                          itemsize: int = 4) -> float:
    """Wire bytes per ITERATION per TENANT of the batched solve.

    The shared Gram part (sb^2 words per outer step) splits across all T
    tenants, so this drops toward the ``b * logp`` floor of the per-tenant
    residual row as T grows -- the amortization curve serve_bench records
    next to measured solves/s.
    """
    c = batched_costs(d, n, P, b, s, s, tenants, formulation)
    return c.bandwidth * itemsize / (s * tenants)


def batched_solves_per_second(machine: MachineModel, *, d: int, n: int,
                              P: int, b: int, H: int, s: int = 1,
                              tenants: int = 1,
                              formulation: str = "primal") -> float:
    """Modeled solve throughput of the batched engine: T solves of H
    iterations finish in ONE batched critical path, so

        solves/s = T / time(batched_costs(T))

    with the sync term ``alpha * L`` amortized across the tenant axis (L is
    independent of T).  At T=1 this is exactly the single-solve rate."""
    t = batched_costs(d, n, P, b, H, s, tenants, formulation).time(machine)
    return tenants / t


# --------------------------------------------------------------------------
# Wire schedules: monolithic psum vs the pipelined ring (DESIGN.md section 9)
# --------------------------------------------------------------------------
# The Theorem 6/7 rows above charge the packet reduction as a tree all-reduce
# sitting SERIALLY on the critical path: 2 log2(P) messages, payload * log2(P)
# words, nothing overlapped.  The "pipelined" backend decomposes that psum
# into a dimension-wise ring (per mesh axis of size P_i: a reduce-scatter of
# P_i - 1 collective-permute hops followed by an all-gather of P_i - 1 hops)
# and software-pipelines the outer scan so step k+1's Gram contraction -- the
# one packet term with no data dependence on the in-flight reduction -- runs
# between the hops.  The functions below model both schedules with the same
# alpha-beta constants so the dryrun and pipeline_bench can put the exposed
# wire time of each next to the other.

def ring_wire_costs(payload_words: float, axis_sizes) -> tuple[float, float]:
    """(messages, words) on the critical path of ONE dimension-wise ring
    all-reduce of ``payload_words``: per mesh axis of size P > 1,
    ``2 (P - 1)`` collective-permute hops moving ``2 payload (P - 1)/P``
    words (reduce-scatter + all-gather of 1/P-size chunks); size-1 axes are
    free.  The hop count is exactly engine.ring_hops' affine ``(2, -2)`` law
    the analysis sweep machine-verifies against the lowered HLO."""
    L = sum(2 * (P - 1) for P in axis_sizes)
    W = sum(2 * payload_words * (P - 1) / P for P in axis_sizes if P > 1)
    return float(L), float(W)


def psum_wire_time(machine: MachineModel, payload_words: float, P: int) -> float:
    """Serial tree all-reduce: the wire term of the Theorem 6/7 rows."""
    return (machine.alpha * 2 * _logp(P)
            + machine.beta * payload_words * _logp(P))


def ring_wire_time(machine: MachineModel, payload_words: float,
                   axis_sizes) -> float:
    """End-to-end time of the decomposed ring reduction (no overlap credit;
    that is ``pipeline_schedule``'s job)."""
    L, W = ring_wire_costs(payload_words, axis_sizes)
    return machine.alpha * L + machine.beta * W


def pipeline_schedule(machine: MachineModel, *, d: int, n: int, axis_sizes,
                      b: int, s: int, tenants: int = 1,
                      formulation: str = "primal", guard: bool = False,
                      fma: float = 2.0) -> dict:
    """Alpha-beta-gamma model of ONE outer step under both wire schedules.

    The overlappable work per outer step is the step's own compute -- the
    shared Gram contraction (issued one step ahead by the pipelined scan) plus
    the T tenants' sweeps and deferred updates -- so the ring hides
    ``t_hidden = min(t_compute, t_wire_ring)`` of its wire and exposes the
    rest; the monolithic psum exposes ALL of its wire by construction.

    ``fma=2.0`` converts the Theorem-style cell counts (one per multiply-add)
    to hardware flops, since machine peaks (e.g. 197 TFLOP/s) count the FMA
    as two -- without it every compute time would be understated 2x against
    the wire terms.

    Returns a dict with ``payload_words``, ``hops``, ``t_compute``,
    ``t_wire_psum``, ``t_wire_ring``, ``t_hidden``, ``t_exposed_ring``,
    ``t_exposed_psum``, ``overlap_ratio`` (hidden/total ring wire, in
    [0, 1]), and ``step_speedup`` (serial-psum step over pipelined step).
    """
    axis_sizes = tuple(int(P) for P in axis_sizes)
    P = math.prod(axis_sizes)
    sb = s * b
    payload = sb * sb + tenants * sb
    if guard:
        from .engine import _HEALTH_WORDS
        payload += _HEALTH_WORDS
    # one outer step == the H=s slice of the batched critical path
    F_step = batched_costs(d, n, P, b, s, s, tenants, formulation).flops
    t_compute = machine.gamma * fma * F_step
    t_psum = psum_wire_time(machine, payload, P)
    t_ring = ring_wire_time(machine, payload, axis_sizes)
    t_hidden = min(t_compute, t_ring)
    ratio = t_hidden / t_ring if t_ring > 0 else 1.0
    t_step_serial = t_compute + t_psum
    t_step_pipe = max(t_compute, t_ring)
    return {
        "payload_words": float(payload),
        "hops": float(ring_wire_costs(payload, axis_sizes)[0]),
        "t_compute": t_compute,
        "t_wire_psum": t_psum,
        "t_wire_ring": t_ring,
        "t_hidden": t_hidden,
        "t_exposed_ring": t_ring - t_hidden,
        "t_exposed_psum": t_psum,
        "overlap_ratio": ratio,
        "step_speedup": t_step_serial / t_step_pipe if t_step_pipe else 1.0,
    }


def overlap_ratio(machine: MachineModel, *, d: int, n: int, axis_sizes,
                  b: int, s: int, tenants: int = 1,
                  formulation: str = "primal", guard: bool = False) -> float:
    """Fraction of the ring reduction's wire time hidden behind compute --
    the acceptance number pipeline_bench records.  Latency-bound single-
    tenant cells sit near 0 (there is almost no compute to hide behind 60
    hops); the batched serving point is where the schedule pays."""
    return pipeline_schedule(machine, d=d, n=n, axis_sizes=axis_sizes, b=b,
                             s=s, tenants=tenants, formulation=formulation,
                             guard=guard)["overlap_ratio"]


# --------------------------------------------------------------------------
# Per-device HBM traffic of the Gram-packet hot path (the gather term)
# --------------------------------------------------------------------------
# The alpha-beta-gamma model above counts inter-device words (W); on TPU the
# on-device roofline is governed by HBM bytes instead, and the dominant term
# of one outer iteration is how often the sampled sb x n panel crosses HBM.
#
# Both Gram kernels stream their row/column operand tiles from HBM once per
# grid cell, so with B = ceil(sb/bm) row blocks the Gram contraction itself
# reads the panel's worth of rows B times (B^2 cells x bm rows each, halved
# by the symmetric skip but doubled by the two operand panels).  On top of
# that:
#
# materialized baseline (PR 1): Y = X[flat, :] built before the kernel
#     read X rows (gather) + write Y + B x read Y (Gram) + read Y (apply)
#     -> B + 3 panel crossings.
# panel-free (gram_packet_sampled / panel_apply): the kernel gathers rows
#     straight to VMEM -> B x read X rows (Gram) + read X rows (apply), no
#     materialized panel -> B + 1 crossings.
#
# The win is exactly the gather write + gather read + one re-read that the
# fused kernel skips: ratio (B+1)/(B+3), i.e. ~1/2 at the solvers' operating
# points (sb <= bm=128 => B=1) and fading as sb/bm grows -- which is why the
# tuning table keeps bm at the sb it can afford in VMEM.
#
# Column-major gather (layout="cols", the dual's transpose-free operand): the
# kernel fetches each sampled column as a lane-aligned (bk x LANE) slab of
# the ORIGINAL layout and selects the target lane in VMEM, so every panel
# crossing over-reads by the lane width -- ``lane`` x the useful column bytes
# (worst case: sampled columns sharing a lane group are not deduplicated).
# That amplified per-iteration traffic is what the layout trades for
# dropping the pre-transpose's 2x resident dataset (``dual_operand_tradeoff``
# puts both sides of the trade next to each other; ``make bench-smoke``
# records them).
#
# Shared smaller terms (both schedules): the residual operand u (n), the
# alpha/w tile read+write (2n), the sb x sb Gram + sb residual written once,
# and the sb-vector of updates read back by the apply.

def packet_hbm_bytes(sb: int, n: int, itemsize: int = 4,
                     panel_free: bool = True, bm: int = 128,
                     layout: str = "rows", lane: int = 128) -> float:
    """Modeled HBM bytes of ONE outer iteration's packet + deferred apply.
    ``n`` is the contraction length (operand columns for ``layout="rows"``;
    X's rows d for ``layout="cols"``); ``bm`` is the kernel's sample-tile
    size (pass the tuning-table pick).  ``layout="cols"`` applies the
    lane-slab amplification ``lane`` to the panel-crossing term."""
    if layout not in ("rows", "cols"):
        raise ValueError(f"unknown layout {layout!r}")
    amp = lane if layout == "cols" else 1
    panel = sb * n * amp
    blocks = -(-sb // max(bm, 1))
    shared = 3 * n + sb * sb + 2 * sb
    crossings = (blocks + 1) if panel_free else (blocks + 3)
    return float((crossings * panel + shared) * itemsize)


def packet_traffic_breakdown(sb: int, n: int, itemsize: int = 4,
                             bm: int = 128) -> dict:
    """Both schedules' modeled bytes plus the ratio (the bench-smoke
    baseline records this; (B+1)/(B+3) ~= 1/2 while sb <= bm)."""
    base = packet_hbm_bytes(sb, n, itemsize, panel_free=False, bm=bm)
    fused = packet_hbm_bytes(sb, n, itemsize, panel_free=True, bm=bm)
    return {"baseline_bytes": base, "panel_free_bytes": fused,
            "ratio": fused / base}


def dual_operand_tradeoff(d: int, n: int, sb: int, itemsize: int = 4,
                          bm_rows: int | None = None,
                          bm_cols: int | None = None,
                          lane: int = 128) -> dict:
    """Both sides of the dual-layout trade, per operand strategy:

    * ``pretranspose`` (PRs 2-4): row-gather traffic on ``X.T``, but the
      transposed copy doubles the resident dataset for the whole solve (plus
      the one-time 2 d n transpose crossing, not amortized here).
    * ``colgather`` (PR 5): the original layout stays the only copy; each
      panel crossing pays the ``lane``-slab amplification instead.

    Each schedule is modeled at ITS OWN kernel's tile pick (the tuning-table
    (sb, d, layout) entry unless ``bm_rows``/``bm_cols`` pin them) -- using
    one bm for both would misstate whichever kernel runs different tiles.
    ``resident_bytes`` counts the dataset copies plus the solve's vectors
    (w in R^d, alpha and y in R^n); the bench-smoke baseline records the
    measured XLA figures next to these modeled ones.
    """
    if bm_rows is None or bm_cols is None:
        from repro.kernels.gram import tuning  # keep module import light
        if bm_rows is None:
            bm_rows = tuning.pick_tiles(sb, d, np.float32, layout="rows")[0]
        if bm_cols is None:
            bm_cols = tuning.pick_tiles(sb, d, np.float32, layout="cols")[0]
    vectors = (d + 2 * n) * itemsize
    data = d * n * itemsize
    return {
        "pretranspose": {
            "resident_bytes": float(2 * data + vectors),
            "hbm_bytes_per_iter": packet_hbm_bytes(
                sb, d, itemsize, panel_free=True, bm=bm_rows, layout="rows"),
        },
        "colgather": {
            "resident_bytes": float(data + vectors),
            "hbm_bytes_per_iter": packet_hbm_bytes(
                sb, d, itemsize, panel_free=True, bm=bm_cols, layout="cols",
                lane=lane),
        },
    }


# Per-core VMEM on the target parts (TPU v4/v5e: ~16 MiB).  The plan pass
# (repro.analysis.plan_pass) validates every tuning-table entry and PacketPlan
# against this budget; keep it in the cost model so the modeled and the
# checked footprints come from one place.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024


def kernel_vmem_bytes(bm: int, bk: int, itemsize: int = 4,
                      layout: str = "rows", lane: int = 128) -> float:
    """Static VMEM footprint of the Gram-packet kernels at (bm, bk) tiles --
    the max over the layout's gram and apply kernels, from their declared
    scratch + block shapes (sampled_kernel.py / sampled_colmajor.py):

    * ``rows`` gram: two gathered (bm, bk) panels + the (bm, bm) G tile +
      the bk-length u tile and bm-length r tile.
    * ``cols`` gram: two extracted (bm, bk) panels + two (bm, bk, lane)
      slabs (the lane-aligned fetch) + the same G/u/r tiles.
    * apply kernels hold one panel (+ one slab for ``cols``) + the bk/bm
      vector tiles; always <= the gram footprint, kept for completeness.

    No double-buffering multiplier: the gathered panels are scratch (manually
    DMA'd), not pipelined BlockSpec operands.
    """
    if layout not in ("rows", "cols"):
        raise ValueError(f"unknown layout {layout!r}")
    slab = bm * bk * lane if layout == "cols" else 0
    gram = 2 * bm * bk + 2 * slab + bm * bm + bk + bm
    apply_ = bm * bk + slab + bk + bm
    return float(max(gram, apply_) * itemsize)


def packet_memory_time(sb: int, n: int, hbm_bytes_per_s: float,
                       itemsize: int = 4, panel_free: bool = True,
                       bm: int = 128) -> float:
    """Memory-bound roofline time of one outer iteration (the Gram itself is
    MXU-bound only once n/P is small enough that the packet fits in VMEM)."""
    return packet_hbm_bytes(sb, n, itemsize, panel_free, bm) / hbm_bytes_per_s


def best_s(cost_fn, machine: MachineModel, d: int, n: int, P: int, b: int,
           H: int, s_grid=None) -> tuple[int, float]:
    """min_s T(s): returns (s*, T(s*)).  s=1 recovers the classical algorithm,
    so T(s*) <= T(classical) by construction -- the paper's tuning story."""
    if s_grid is None:
        s_grid = [1, 2, 5, 10, 25, 40, 50, 100, 200, 300, 600, 750, 1000]
    best = (1, float("inf"))
    for s in s_grid:
        if H % s:
            continue
        t = cost_fn(d, n, P, b, H, s).time(machine)
        if t < best[1]:
            best = (s, t)
    return best


def strong_scaling(machine: MachineModel, *, d: int, n: int, b: int, H: int,
                   Ps, s_grid=None) -> dict:
    """Figure 8: fixed problem, growing P.  Returns per-P classical time,
    best-s CA time, the chosen s, and the speedup."""
    out = {"P": [], "t_classical": [], "t_ca": [], "s": [], "speedup": []}
    for P in Ps:
        t1 = bcd_costs(d, n, P, b, H, 1).time(machine)
        s, ts = best_s(bcd_costs, machine, d, n, P, b, H, s_grid)
        out["P"].append(P)
        out["t_classical"].append(t1)
        out["t_ca"].append(ts)
        out["s"].append(s)
        out["speedup"].append(t1 / ts)
    return {k: np.asarray(v) for k, v in out.items()}


def weak_scaling(machine: MachineModel, *, d: int, n_per_P: int, b: int, H: int,
                 Ps, s_grid=None) -> dict:
    """Figure 9: n = n_per_P * P."""
    out = {"P": [], "t_classical": [], "t_ca": [], "s": [], "speedup": []}
    for P in Ps:
        n = n_per_P * P
        t1 = bcd_costs(d, n, P, b, H, 1).time(machine)
        s, ts = best_s(bcd_costs, machine, d, n, P, b, H, s_grid)
        out["P"].append(P)
        out["t_classical"].append(t1)
        out["t_ca"].append(ts)
        out["s"].append(s)
        out["speedup"].append(t1 / ts)
    return {k: np.asarray(v) for k, v in out.items()}

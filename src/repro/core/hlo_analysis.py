"""HLO op analysis: the one parser behind every ``compiled.as_text()`` reader.

This is the measurement backbone for (a) the paper's latency claim -- the
number of collectives on the critical path drops by exactly ``s`` in CA-BCD /
CA-BDCD, which we verify by counting ops in compiled HLO -- and (b) the
roofline collective term, which ``cost_analysis()`` does not report, so we
parse ``compiled.as_text()`` and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.  The static
contract engine (``repro.analysis``) builds its HLO pass on the same parser:
:func:`parse_named_ops` generalizes the line scan to arbitrary opcodes
(transpose, gather, fusion) so the PR-5 "no dual pre-transpose" and PR-2
"panel never materializes" guarantees are checked from one source of truth.

Conventions, re-verified against the pinned JAX 0.4.37 CPU-backend HLO (the
docstring previously claimed 0.8.2 -- drift; fixture snapshots of the real
0.4.37 output live in ``tests/fixtures/hlo/`` so the parser is unit-tested
without a live compile):

  %name = f32[8,9]{1,0} all-reduce(f32[8,9]{1,0} %op), channel_id=1,
      replica_groups={{0,1,2,3,4,5,6,7}}, use_global_device_ids=true, ...

* ``replica_groups`` appears in BOTH forms on 0.4.37: the brace form
  ``{{0,1,...}}`` (shard_map/GSPMD output, group size = ids per group) and
  the iota form ``[2,4]<=[8]`` (group size = second bracket entry).
* Async collectives split into ``-start``/``-done`` pairs; the ``-start``
  result is the tuple ``(operand-shape(s), result-shape(s))``, so its summed
  byte size is halved and the ``-done`` line is skipped -- each logical
  collective is counted exactly once.
* Result-shape bytes are parsed from the type; operand bytes are derived per
  op kind (all-gather results are group_size x the operand, reduce-scatter
  the inverse).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "c64": 8, "c128": 16,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_OP_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>" + "|".join(COLLECTIVE_KINDS) + r")(?P<phase>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    kind: str
    result_bytes: float   # bytes of the op's result shape(s)
    operand_bytes: float  # derived operand bytes ("words on the wire" source)
    link_bytes: float     # ring-model bytes crossing links per device
    group_size: int
    line: str


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        size = _DTYPE_BYTES.get(dtype)
        if size is None:
            continue  # token[...] that is not a dtype (e.g. sharding annotations)
        n = 1
        if dims:
            for piece in dims.split(","):
                n *= int(piece)
        total += n * size
    return total


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [p for p in m.group(1).replace(" ", "").split(",") if p]
        return max(len(ids), 1)
    return default


def parse_collectives(hlo_text: str, total_devices: int | None = None) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("phase") == "-done":
            continue  # paired with a counted -start
        kind = m.group("kind")
        type_str = m.group("type")
        result = _shape_bytes(type_str)
        if m.group("phase") == "-start" and type_str.startswith("("):
            # -start result is (operand(s), result(s)); halve to avoid double count.
            result /= 2
        g = _group_size(line, default=total_devices or 1)
        if kind == "all-gather":
            operand = result / max(g, 1)
            link = result * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            operand = result * g
            link = operand * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            operand = result
            link = 2 * result * (g - 1) / max(g, 1)
        elif kind in ("all-to-all", "ragged-all-to-all"):
            operand = result
            link = result * (g - 1) / max(g, 1)
        else:  # collective-permute / broadcast
            operand = result
            link = result
        ops.append(CollectiveOp(kind, result, operand, link, g, line.strip()[:200]))
    return ops


@dataclasses.dataclass(frozen=True)
class CollectiveSummary:
    count: int
    operand_bytes: float
    link_bytes: float
    by_kind: dict

    def __str__(self) -> str:
        parts = [f"{k}: n={v[0]} operand={v[1]:.3e}B link={v[2]:.3e}B"
                 for k, v in sorted(self.by_kind.items())]
        return (f"collectives total n={self.count} operand={self.operand_bytes:.3e}B "
                f"link={self.link_bytes:.3e}B | " + "; ".join(parts))


def summarize(ops: Iterable[CollectiveOp]) -> CollectiveSummary:
    by_kind: dict[str, list] = {}
    count = 0
    ob = lb = 0.0
    for op in ops:
        count += 1
        ob += op.operand_bytes
        lb += op.link_bytes
        ent = by_kind.setdefault(op.kind, [0, 0.0, 0.0])
        ent[0] += 1
        ent[1] += op.operand_bytes
        ent[2] += op.link_bytes
    return CollectiveSummary(count, ob, lb, {k: tuple(v) for k, v in by_kind.items()})


def collective_summary(hlo_text: str, total_devices: int | None = None) -> CollectiveSummary:
    return summarize(parse_collectives(hlo_text, total_devices))


def count_in_compiled(compiled) -> CollectiveSummary:
    """Summary for a jax ``Compiled`` object."""
    return collective_summary(compiled.as_text())


# ---------------------------------------------------------------------------
# Generic named-op scan -- the contract engine's view of the HLO text.
# ---------------------------------------------------------------------------

# An HLO instruction line:  %name = TYPE opcode(OPERANDS), attrs...
# TYPE is either a tuple "(f32[..], ...)" or "dtype[dims]{layout}".
_NAMED_OP_RE = re.compile(
    r"(?P<result>%[\w.\-]+)\s*=\s*"
    r"(?P<type>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<opcode>[a-z][a-z0-9\-]*)\(")


@dataclasses.dataclass(frozen=True)
class HloOp:
    """One parsed HLO instruction: opcode, result shapes, raw line."""
    opcode: str
    result_name: str
    # ((dtype, (dims...)), ...): every dtype[...] in the result type -- one
    # entry for plain results, several for tuple-shaped (-start) results.
    result_shapes: tuple
    line: str

    def shapes(self) -> tuple:
        """Just the dim tuples, dtype dropped."""
        return tuple(dims for _, dims in self.result_shapes)

    def dtypes(self) -> tuple:
        return tuple(dt for dt, _ in self.result_shapes)


def _parse_shapes(type_str: str) -> tuple:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue  # not a dtype token (layout/sharding noise)
        out.append((dtype, tuple(int(p) for p in dims.split(",")) if dims else ()))
    return tuple(out)


def parse_named_ops(hlo_text: str, opcodes: Iterable[str] | None = None) -> list[HloOp]:
    """Scan HLO text for instruction lines, optionally filtered by opcode.

    The contract engine uses this for the non-collective checks: ``transpose``
    ops whose result is operand-shaped (the legacy dual pre-transpose),
    ``gather``/``fusion`` ops whose result is a materialized (sb, n_local)
    panel, and dtype inspection of the collectives for the f64 packet check.
    Operand shapes inside the parens are deliberately NOT parsed -- result
    shapes are enough to identify every contract violation by shape, and the
    operand syntax varies more across JAX versions.
    """
    wanted = set(opcodes) if opcodes is not None else None
    ops: list[HloOp] = []
    for line in hlo_text.splitlines():
        m = _NAMED_OP_RE.search(line)
        if not m:
            continue
        opcode = m.group("opcode")
        if wanted is not None and opcode not in wanted:
            continue
        ops.append(HloOp(opcode, m.group("result"),
                         _parse_shapes(m.group("type")), line.strip()[:200]))
    return ops


def collective_dtypes(hlo_text: str) -> set:
    """Dtypes carried by every counted collective (``-done`` lines skipped).

    Backs the f64-packet contract: under the x64 test path every packet
    reduction must accumulate in f64, so this set must be ``{"f64"}``.
    """
    dts: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("phase") == "-done":
            continue
        for dt, _ in _parse_shapes(m.group("type")):
            dts.add(dt)
    return dts

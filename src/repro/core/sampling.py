"""Coordinate-block sampling for (CA-)BCD / (CA-)BDCD.

The paper samples ``b`` coordinates uniformly at random *without replacement*
per iteration (Algorithms 1-4, line "choose {i_m} uniformly at random").  In the
communication-avoiding variants all processors must agree on the sampled blocks
without communicating; the paper's mechanism is a shared RNG seed.  In JAX/SPMD
the analogue is: indices are derived from a replicated ``jax.random`` key outside
``shard_map`` and closed over / passed in replicated, which is bit-identical on
every device by construction.

Two modes are provided:

* ``global_uniform`` -- the paper's scheme: each iteration's block is drawn
  uniformly without replacement from ``[n_total]``.  Under a 1D layout of the
  *sampled* dimension this can load-imbalance (Thm. 4/5: balls-in-bins), which
  the paper repairs with an all-to-all.
* ``shard_balanced`` -- TPU adaptation (DESIGN.md section 2.6): each of the P
  shards contributes ``b/P`` coordinates from its own range, so the sampled
  rows are perfectly load balanced and no repartition collective is needed.
  Block selection remains uniform over a subset of the support; convergence
  behaviour is empirically indistinguishable (tests/test_convergence.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

MODES = ("global_uniform", "shard_balanced")


@functools.partial(jax.jit, static_argnums=(1, 2))
def _sample_one(key: jax.Array, n_total: int, b: int) -> jax.Array:
    return jax.random.choice(key, n_total, (b,), replace=False)


def sample_blocks(key: jax.Array, n_total: int, b: int, iters: int,
                  mode: str = "global_uniform", *,
                  n_shards: int | None = None) -> jax.Array:
    """Sample ``iters`` coordinate blocks of size ``b`` from ``[n_total]``.

    Returns int32 ``(iters, b)``.  Within a row: no replacement.  Across rows:
    independent draws (the paper's scheme).  Deterministic in ``key`` -- the
    CA variants re-use the *same* index stream as the classical ones, which is
    what makes the exact-equivalence property testable.

    ``mode="shard_balanced"`` dispatches to :func:`sample_blocks_balanced`
    and needs the shard count: pass ``n_shards=P``.  (It used to fall back to
    ``global_uniform`` silently, which defeats the load-balance guarantee the
    mode exists for.)
    """
    if mode not in MODES:
        raise ValueError(f"unknown sampling mode {mode!r}; expected one of {MODES}")
    if not 1 <= b <= n_total:
        raise ValueError(f"block size b={b} must be in [1, n_total={n_total}]")
    if mode == "shard_balanced":
        if n_shards is None:
            raise ValueError(
                "mode='shard_balanced' needs the shard count: pass "
                "n_shards=P (or call sample_blocks_balanced directly); "
                "refusing to silently fall back to global_uniform")
        return sample_blocks_balanced(key, n_total, b, iters, n_shards)
    if n_shards is not None:
        raise ValueError("n_shards only applies to mode='shard_balanced'")
    keys = jax.random.split(key, iters)
    idx = jax.vmap(lambda k: _sample_one(k, n_total, b))(keys)
    return idx.astype(jnp.int32)


def sample_blocks_balanced(key: jax.Array, n_total: int, b: int, iters: int,
                           n_shards: int) -> jax.Array:
    """Shard-balanced sampling: each shard of ``n_total/n_shards`` contiguous
    coordinates contributes ``b/n_shards`` indices per iteration.

    Requires ``b % n_shards == 0`` and ``n_total % n_shards == 0``.  Every
    device can compute this from the replicated key, and the induced row
    gather touches every shard equally -- the TPU-native replacement for the
    paper's all-to-all repartition (Thm. 4/8).
    """
    if b % n_shards != 0:
        raise ValueError(f"b={b} must be divisible by n_shards={n_shards}")
    if n_total % n_shards != 0:
        raise ValueError(f"n_total={n_total} must be divisible by n_shards={n_shards}")
    per = b // n_shards
    shard_len = n_total // n_shards
    # reshape keeps the trailing key dims so both typed keys (scalar
    # elements) and raw uint32 keys (trailing (2,)) work.
    keys = jax.random.split(key, iters * n_shards)
    keys = keys.reshape(iters, n_shards, *keys.shape[1:])

    def one_iter(ks):
        local = jax.vmap(
            lambda k: jax.random.choice(k, shard_len, (per,), replace=False)
        )(ks)  # (n_shards, per)
        offset = (jnp.arange(n_shards) * shard_len)[:, None]
        return (local + offset).reshape(b)

    idx = jax.vmap(one_iter)(keys)
    return idx.astype(jnp.int32)


def overlap_matrix(flat_idx: jax.Array) -> jax.Array:
    """O[p, q] = 1 if flat_idx[p] == flat_idx[q].

    This is the paper's :math:`\\mathbb{I}^T_{sk+j}\\mathbb{I}_{sk+t}`
    intersection term, computed locally on every device with zero
    communication (the shared-seed trick).  Shape ``(sb, sb)`` for an outer
    iteration with ``s`` inner blocks of size ``b``.
    """
    eq = flat_idx[:, None] == flat_idx[None, :]
    return eq.astype(jnp.result_type(float))

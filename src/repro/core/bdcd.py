"""Dual block coordinate descent (Algorithm 3) and CA-BDCD (Algorithm 4).

Solves the dual problem

    min_alpha  lam/2 ||X alpha/(lam n)||^2 + 1/(2n) ||alpha + y||^2

with the primal iterate maintained through w = -X alpha / (lam n).  With
b' = 1 this is SDCA with the least-squares loss (paper section 3.2).

Since PR 3 these are thin wrappers over the shared s-step engine: the dual is
a :class:`~repro.core.engine.Formulation` (``DualRidge``) plugged into the
same scan that runs the primal -- same driver, same ragged-tail handling,
same distributed backend.

CA identity: the inner loop is block forward substitution against

    A = Y^T Y / (lam n^2) + O / n,   Y = X[:, flat_idx],  O = overlap(flat_idx)

with base_j = (1/n) (Y_j^T w_sk - alpha_sk[idx_j] - y[idx_j]); diagonal blocks
of A are the Theta_{sk+j} of Eq. (18).

Data flow (panel-free since PR 2, transpose-free since PR 5): the dual
samples *columns* of X, and the formulation binds a column-major
:class:`~repro.kernels.gram.ColMajorOperand` over the ORIGINAL (d, n) array
-- no ``X.T`` anywhere in the solve path, constructor or scan.  The sampled
Gram ``Y^T Y`` for ``Y = X[:, flat]`` comes straight from (X, flat) via the
lane-aligned column-tile kernels (``kernels/gram/sampled_colmajor.py``), and
the deferred primal updates (Eq. 15/19, ``w -= Y das / (lam n)``) use
``panel_apply`` on the same operand (``X[:, flat] @ das``).

Tradeoff: PRs 2-4 pre-transposed each shard (``Xl.T``) so column sampling
became row sampling -- row-contiguous DMA, but a second resident copy of the
dataset for the length of the solve.  The column-gather operand drops that
copy; its slab fetches over-read by the 128-lane width (worst case, no
lane-group dedup), which ``cost_model.packet_hbm_bytes(layout="cols")``
models and ``make bench-smoke`` records next to the halved resident
footprint.
"""
from __future__ import annotations

import jax

from .engine import (DualRidge, SolveResult, SolverPlan, register_solver,
                     s_step_solve)

DUAL = DualRidge()


def bdcd(X: jax.Array, y: jax.Array, lam: float, b: int, iters: int,
         key: jax.Array, *, alpha0: jax.Array | None = None,
         idx: jax.Array | None = None, w_ref: jax.Array | None = None,
         impl: str | None = None,
         tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical BDCD, Algorithm 3: the s-step engine at s=1.  ``b`` is the
    paper's b'.  ``impl`` selects the Gram-packet backend
    (``repro.core.gram_packet``)."""
    plan = SolverPlan(b=b, s=1, impl=impl, tiles=tiles)
    return s_step_solve(DUAL, plan, X, y, lam, iters, key, x0=alpha0, idx=idx,
                        w_ref=w_ref)


def ca_bdcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int, iters: int,
            key: jax.Array, *, alpha0: jax.Array | None = None,
            idx: jax.Array | None = None, w_ref: jax.Array | None = None,
            track_cond: bool = False, impl: str | None = None,
            tiles: tuple[int, int] | None = None, guard: bool = False,
            fault=None, step0: int = 0) -> SolveResult:
    """CA-BDCD, Algorithm 4: the s-step engine at s>1.  Same index stream as
    :func:`bdcd` => identical iterates in exact arithmetic; one sb' x sb'
    Gram-packet all-reduce per outer iteration in the distributed version
    (backend per ``impl``).  ``iters`` need not be a multiple of ``s``.
    ``guard``/``fault``/``step0`` arm the health guard, the test-only fault
    hook, and the segmented-solve step offset (DESIGN.md section 7)."""
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles, track_cond=track_cond,
                      guard=guard, fault=fault)
    return s_step_solve(DUAL, plan, X, y, lam, iters, key, x0=alpha0, idx=idx,
                        w_ref=w_ref, step0=step0)


# ca_bdcd at s=1 is classical bdcd, so it is the canonical registry entry.
register_solver("dual", "local", ca_bdcd)

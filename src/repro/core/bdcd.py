"""Dual block coordinate descent (Algorithm 3) and CA-BDCD (Algorithm 4).

Solves the dual problem

    min_alpha  lam/2 ||X alpha/(lam n)||^2 + 1/(2n) ||alpha + y||^2

with the primal iterate maintained through w = -X alpha / (lam n).  With
b' = 1 this is SDCA with the least-squares loss (paper section 3.2).

CA identity: the inner loop is block forward substitution against

    A = Y^T Y / (lam n^2) + O / n,   Y = X[:, flat_idx],  O = overlap(flat_idx)

with base_j = (1/n) (Y_j^T w_sk - alpha_sk[idx_j] - y[idx_j]); diagonal blocks
of A are the Theta_{sk+j} of Eq. (18).

Data flow (panel-free since PR 2): the dual samples *columns* of X, so the
solvers hold ``XT = X.T`` -- materialized once, outside the hot loop -- and
the sampled Gram ``Y^T Y = XT[flat, :] XT[flat, :]^T`` comes straight from
(XT, flat) via ``gram_packet_sampled`` without ever forming the (d, sb)
panel.  The deferred primal updates (Eq. 15/19, ``w -= Y das / (lam n)``) use
``panel_apply(XT, flat, das)`` == ``X[:, flat] @ das`` from the same pair.

Memory tradeoff: XT doubles the dataset's resident footprint for the length
of the solve (X itself stays live for the objective metrics and the caller's
buffer).  This is deliberate -- a column-sampled kernel would need
lane-strided DMA gathers, which defeats the row-contiguous copies the
sampled kernel relies on -- and it trades a one-time O(dn) cost for zero
per-iteration panel traffic; a column-major sampled variant that avoids the
second copy is a ROADMAP open item.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_packet_sampled, panel_apply

from .bcd import SolveResult, _metrics, _tile_kw
from .sampling import overlap_matrix, sample_blocks
from .subproblem import block_forward_substitution, solve_spd


def bdcd(X: jax.Array, y: jax.Array, lam: float, b: int, iters: int,
         key: jax.Array, *, alpha0: jax.Array | None = None,
         idx: jax.Array | None = None, w_ref: jax.Array | None = None,
         impl: str | None = None,
         tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical BDCD, Algorithm 3.  ``b`` is the paper's b'.  ``impl``
    selects the Gram-packet backend (``repro.core.gram_packet``)."""
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, n, b, iters)
    alpha = jnp.zeros((n,), X.dtype) if alpha0 is None else alpha0
    w = -X @ alpha / (lam * n)
    XT = X.T           # once, outside the hot loop (columns become rows)
    tk = _tile_kw(tiles)

    def step(carry, idx_h):
        w, alpha = carry
        # One fused panel-free packet: Theta = Xc^T Xc / (lam n^2) + I/n
        # (regularized diagonal fused) and the raw projection Xc^T w
        # (scale_r=1), with Xc^T = XT[idx_h, :] gathered inside the kernel.
        Theta, u = gram_packet_sampled(XT, idx_h, w, scale=1.0 / (lam * n * n),
                                       scale_r=1.0, reg=1.0 / n, impl=impl,
                                       **tk)
        rhs = (u - alpha[idx_h] - y[idx_h]) / n            # Eq. (17)
        da = solve_spd(Theta, rhs)
        alpha = alpha.at[idx_h].add(da)
        # Eq. (15): w -= Xc @ da / (lam n) == XT[idx_h, :]^T da / (lam n).
        w = w - panel_apply(XT, idx_h, da, impl=impl, **tk) / (lam * n)
        return (w, alpha), _metrics_dual(X, alpha, w, y, lam, w_ref)

    (w, alpha), hist = jax.lax.scan(step, (w, alpha), idx)
    return SolveResult(w, alpha, hist)


def _metrics_dual(X, alpha, w, y, lam, w_ref):
    # Primal objective evaluated at the dual-generated primal iterate w.
    # X^T w is O(dn); we instead track it through the cheap surrogate
    # ||alpha + y|| terms when benchmarking large problems, but for the paper
    # figures (small d,n) the exact primal objective is affordable and matches
    # the paper's plots.
    n = alpha.shape[0]
    r = X.T @ w - y
    m = {"objective": 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)}
    if w_ref is not None:
        m["sol_err"] = jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)
    return m


def ca_bdcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int, iters: int,
            key: jax.Array, *, alpha0: jax.Array | None = None,
            idx: jax.Array | None = None, w_ref: jax.Array | None = None,
            track_cond: bool = False, impl: str | None = None,
            tiles: tuple[int, int] | None = None) -> SolveResult:
    """CA-BDCD, Algorithm 4.  Same index stream as :func:`bdcd` => identical
    iterates in exact arithmetic; one sb' x sb' Gram-packet all-reduce per
    outer iteration in the distributed version (backend per ``impl``)."""
    d, n = X.shape
    if iters % s != 0:
        raise ValueError(f"iters={iters} must be a multiple of s={s}")
    if idx is None:
        idx = sample_blocks(key, n, b, iters)
    idx = idx.reshape(iters // s, s, b)
    alpha = jnp.zeros((n,), X.dtype) if alpha0 is None else alpha0
    w = -X @ alpha / (lam * n)
    XT = X.T           # once, outside the hot loop
    sb = s * b
    tk = _tile_kw(tiles)

    def outer(carry, idx_k):
        w, alpha = carry
        flat = idx_k.reshape(sb)
        # One fused panel-free packet: gram = Y^T Y / (lam n^2) + I/n and the
        # raw projection Y^T w for Y = X[:, flat] (i.e. Y^T = XT[flat, :],
        # gathered inside the kernel); one all-reduce in the distributed
        # version.
        gram, u = gram_packet_sampled(XT, flat, w, scale=1.0 / (lam * n * n),
                                      scale_r=1.0, reg=1.0 / n, impl=impl,
                                      **tk)
        O = overlap_matrix(flat).astype(X.dtype)
        # I/n is already on gram's diagonal; add only the off-diagonal
        # duplicate-index overlap terms (O's diagonal is exactly 1).
        A = gram + (O - jnp.eye(sb, dtype=X.dtype)) / n
        base = (u - alpha[flat] - y[flat]) / n             # Eq. (18) non-correction terms
        das = block_forward_substitution(A, base, s, b)

        def inner(c, j):
            wj, aj = c
            sl = jax.lax.dynamic_slice_in_dim
            idx_j = sl(flat, j * b, b)
            da_j = sl(das, j * b, b)
            aj = aj.at[idx_j].add(da_j)
            wj = wj - panel_apply(XT, idx_j, da_j, impl=impl, **tk) / (lam * n)
            return (wj, aj), _metrics_dual(X, aj, wj, y, lam, w_ref)

        (w, alpha), hist = jax.lax.scan(inner, (w, alpha), jnp.arange(s))
        if track_cond:
            # gram already carries the I/n-regularized diagonal (packet reg).
            hist["gram_cond"] = jnp.full((s,), jnp.linalg.cond(gram))
        return (w, alpha), hist

    (w, alpha), hist = jax.lax.scan(outer, (w, alpha), idx)
    hist = {k: v.reshape(iters, *v.shape[2:]) for k, v in hist.items()}
    return SolveResult(w, alpha, hist)

"""Primal block coordinate descent (Algorithm 1) and its communication-avoiding
variant CA-BCD (Algorithm 2) for the ridge problem

    min_w  lam/2 ||w||^2 + 1/(2n) ||X^T w - y||^2,      X in R^{d x n}.

Single-device reference implementations.  The distributed (shard_map) versions
in ``repro.core.distributed`` compute identical iterates; the equivalence is
tested bit-for-bit.  Both classical and CA variants consume the *same*
pre-sampled index stream, so CA-BCD(s) reproduces BCD's iterates exactly in
exact arithmetic -- the paper's central claim (tested in float64).

Key identity used throughout (DESIGN.md section 1): the CA inner loop is a block
forward substitution against

    A = (1/n) Y Y^T + lam * O,     Y = X[flat_idx, :],  O = overlap(flat_idx)

whose diagonal blocks are the per-iteration Gamma_{sk+j} and whose strictly
lower blocks carry both correction sums of Eq. (8).

Data flow (panel-free since PR 2): the hot loops never materialize the sampled
panel ``Y = X[flat, :]``.  The sb x sb packet comes straight from (X, flat)
via ``gram_packet_sampled`` -- on TPU the kernel scalar-prefetches the block
indices and DMA-gathers the sampled rows HBM->VMEM -- and the deferred vector
updates (Eqs. 5/10, ``alpha += Y^T dws``) are computed from the same (X, flat)
pair by ``panel_apply``.  The panel's three HBM crossings per outer iteration
(gather write, Gram read, apply read) drop to zero; only the sampled rows of X
are read, once per consumer (see ``repro.core.cost_model.packet_hbm_bytes``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_packet_sampled, panel_apply

from .sampling import overlap_matrix, sample_blocks
from .subproblem import block_forward_substitution, solve_spd


class SolveResult(NamedTuple):
    w: jax.Array          # (d,) primal iterate
    alpha: jax.Array      # (n,) residual-form auxiliary alpha = X^T w
    history: dict         # metric name -> (iters,) array (per inner iteration)


def objective(X: jax.Array, w: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    """f(X, w, y) = 1/(2n) ||X^T w - y||^2 + lam/2 ||w||^2."""
    n = X.shape[1]
    r = X.T @ w - y
    return 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)


def _objective_from_alpha(alpha, w, y, lam):
    # alpha == X^T w is maintained by the residual-form recurrence, so the
    # objective costs O(n + d) per iteration instead of O(dn).
    n = alpha.shape[0]
    r = alpha - y
    return 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)


def _metrics(alpha, w, y, lam, w_ref):
    m = {"objective": _objective_from_alpha(alpha, w, y, lam)}
    if w_ref is not None:
        m["sol_err"] = jnp.linalg.norm(w - w_ref) / jnp.linalg.norm(w_ref)
    return m


def _tile_kw(tiles):
    if tiles is None:
        return {}
    return {"bm": tiles[0], "bk": tiles[1]}


def bcd(X: jax.Array, y: jax.Array, lam: float, b: int, iters: int,
        key: jax.Array, *, w0: jax.Array | None = None,
        idx: jax.Array | None = None, w_ref: jax.Array | None = None,
        impl: str | None = None,
        tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical BCD, Algorithm 1 (residual form).  One Gram + one subproblem
    per iteration; in the distributed setting this is one synchronization per
    iteration, which is what the CA variant removes.  ``impl`` selects the
    Gram-packet backend (``repro.core.gram_packet``); ``tiles`` pins the
    kernel's (bm, bk) instead of the autotuned pick."""
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, d, b, iters)
    w = jnp.zeros((d,), X.dtype) if w0 is None else w0
    alpha = X.T @ w if w0 is not None else jnp.zeros((n,), X.dtype)
    tk = _tile_kw(tiles)

    def step(carry, idx_h):
        w, alpha = carry
        # One fused panel-free packet: Gamma = Xb Xb^T / n + lam I and the
        # residual contribution Xb (y - alpha) / n of the Eq. (7) rhs, with
        # Xb = X[idx_h, :] gathered inside the kernel.
        Gamma, r_x = gram_packet_sampled(X, idx_h, y - alpha, scale=1.0 / n,
                                         reg=lam, impl=impl, **tk)
        r = r_x - lam * w[idx_h]                           # Eq. (7) rhs
        dw = solve_spd(Gamma, r)
        w = w.at[idx_h].add(dw)
        alpha = alpha + panel_apply(X, idx_h, dw, impl=impl, **tk)  # Eq. (5)
        return (w, alpha), _metrics(alpha, w, y, lam, w_ref)

    (w, alpha), hist = jax.lax.scan(step, (w, alpha), idx)
    return SolveResult(w, alpha, hist)


def ca_bcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int, iters: int,
           key: jax.Array, *, w0: jax.Array | None = None,
           idx: jax.Array | None = None, w_ref: jax.Array | None = None,
           track_cond: bool = False, impl: str | None = None,
           tiles: tuple[int, int] | None = None) -> SolveResult:
    """CA-BCD, Algorithm 2.  ``iters`` counts *inner* iterations; must be a
    multiple of ``s``.  Consumes the same index stream as :func:`bcd` (same
    ``key`` => identical iterates in exact arithmetic).

    Per outer iteration: ONE sb x sb Gram packet (the only communication in
    the distributed version; built panel-free from (X, flat) by the
    ``impl``-selected backend with the lam-regularized diagonal fused in),
    then ``s`` local solves via block forward substitution, then deferred
    vector updates (Eqs. 9-10) from the same (X, flat) pair.
    """
    d, n = X.shape
    if iters % s != 0:
        raise ValueError(f"iters={iters} must be a multiple of s={s}")
    if idx is None:
        idx = sample_blocks(key, d, b, iters)
    idx = idx.reshape(iters // s, s, b)
    w = jnp.zeros((d,), X.dtype) if w0 is None else w0
    alpha = X.T @ w if w0 is not None else jnp.zeros((n,), X.dtype)
    sb = s * b
    tk = _tile_kw(tiles)

    def outer(carry, idx_k):
        w, alpha = carry
        flat = idx_k.reshape(sb)
        # One fused panel-free packet: gram = Y Y^T / n + lam I (regularized
        # diagonal inside the kernel) and r = Y (y - alpha) / n for
        # Y = X[flat, :], gathered inside the kernel; one all-reduce in the
        # distributed version.
        gram, r = gram_packet_sampled(X, flat, y - alpha, scale=1.0 / n,
                                      reg=lam, impl=impl, **tk)
        O = overlap_matrix(flat).astype(X.dtype)           # local: shared-seed trick
        # lam I is already on gram's diagonal; add only the off-diagonal
        # duplicate-index overlap terms (O's diagonal is exactly 1).
        A = gram + lam * (O - jnp.eye(sb, dtype=X.dtype))
        base = r - lam * w[flat]                           # Eq. (8) non-correction terms
        dws = block_forward_substitution(A, base, s, b)

        # Per-inner-iteration metrics, reconstructed locally (test/bench only;
        # the distributed fast path skips this).
        def inner(c, j):
            wj, aj = c
            sl = jax.lax.dynamic_slice_in_dim
            idx_j = sl(flat, j * b, b)
            dw_j = sl(dws, j * b, b)
            wj = wj.at[idx_j].add(dw_j)
            aj = aj + panel_apply(X, idx_j, dw_j, impl=impl, **tk)
            return (wj, aj), _metrics(aj, wj, y, lam, w_ref)

        (w, alpha), hist = jax.lax.scan(inner, (w, alpha), jnp.arange(s))
        if track_cond:
            # gram already carries the lam-regularized diagonal (packet reg).
            hist["gram_cond"] = jnp.full((s,), jnp.linalg.cond(gram))
        return (w, alpha), hist

    (w, alpha), hist = jax.lax.scan(outer, (w, alpha), idx)
    hist = {k: v.reshape(iters, *v.shape[2:]) for k, v in hist.items()}
    return SolveResult(w, alpha, hist)

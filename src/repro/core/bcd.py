"""Primal block coordinate descent (Algorithm 1) and its communication-avoiding
variant CA-BCD (Algorithm 2) for the ridge problem

    min_w  lam/2 ||w||^2 + 1/(2n) ||X^T w - y||^2,      X in R^{d x n}.

Since PR 3 these are thin wrappers over the shared s-step engine
(``repro.core.engine``): classical BCD is the engine at ``s=1``, CA-BCD(s) the
same scan at ``s>1``, and the distributed versions in
``repro.core.distributed`` are the identical driver wrapped in shard_map.
Both variants consume the *same* pre-sampled index stream, so CA-BCD(s)
reproduces BCD's iterates exactly in exact arithmetic -- the paper's central
claim (tested in float64).  ``iters`` need not be a multiple of ``s``: the
engine runs a ragged final outer iteration over the remainder.

Key identity used throughout (DESIGN.md section 1): the CA inner loop is a block
forward substitution against

    A = (1/n) Y Y^T + lam * O,     Y = X[flat_idx, :],  O = overlap(flat_idx)

whose diagonal blocks are the per-iteration Gamma_{sk+j} and whose strictly
lower blocks carry both correction sums of Eq. (8).

Data flow (panel-free since PR 2): the hot loop never materializes the sampled
panel ``Y = X[flat, :]``.  The formulation binds X as a row-major
:class:`~repro.kernels.gram.RowMajorOperand` (the PacketOperand layer,
DESIGN.md section 5.2), so the sb x sb packet comes straight from (X, flat)
via ``gram_packet_sampled`` -- on TPU the kernel scalar-prefetches the block
indices and DMA-gathers the sampled rows HBM->VMEM -- and the deferred vector
updates (Eqs. 5/10, ``alpha += Y^T dws``) are computed from the same (X, flat)
pair by ``panel_apply`` (see ``repro.core.cost_model.packet_hbm_bytes``).
"""
from __future__ import annotations

import jax

from .engine import (PrimalRidge, SolveResult, SolverPlan, register_solver,
                     s_step_solve)

PRIMAL = PrimalRidge()


def objective(X: jax.Array, w: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    """f(X, w, y) = 1/(2n) ||X^T w - y||^2 + lam/2 ||w||^2."""
    n = X.shape[1]
    r = X.T @ w - y
    return 0.5 / n * (r @ r) + 0.5 * lam * (w @ w)


def bcd(X: jax.Array, y: jax.Array, lam: float, b: int, iters: int,
        key: jax.Array, *, w0: jax.Array | None = None,
        idx: jax.Array | None = None, w_ref: jax.Array | None = None,
        impl: str | None = None,
        tiles: tuple[int, int] | None = None) -> SolveResult:
    """Classical BCD, Algorithm 1 (residual form): the s-step engine at s=1.
    One Gram + one subproblem per iteration; in the distributed setting this
    is one synchronization per iteration, which is what the CA variant
    removes.  ``impl`` selects the Gram-packet backend
    (``repro.core.gram_packet``); ``tiles`` pins the kernel's (bm, bk)
    instead of the autotuned pick."""
    plan = SolverPlan(b=b, s=1, impl=impl, tiles=tiles)
    return s_step_solve(PRIMAL, plan, X, y, lam, iters, key, x0=w0, idx=idx,
                        w_ref=w_ref)


def ca_bcd(X: jax.Array, y: jax.Array, lam: float, b: int, s: int, iters: int,
           key: jax.Array, *, w0: jax.Array | None = None,
           idx: jax.Array | None = None, w_ref: jax.Array | None = None,
           track_cond: bool = False, impl: str | None = None,
           tiles: tuple[int, int] | None = None, guard: bool = False,
           fault=None, step0: int = 0) -> SolveResult:
    """CA-BCD, Algorithm 2: the s-step engine at s>1.  ``iters`` counts
    *inner* iterations; a non-multiple of ``s`` runs a ragged final outer
    iteration.  Consumes the same index stream as :func:`bcd` (same ``key``
    => identical iterates in exact arithmetic).

    Per outer iteration: ONE sb x sb Gram packet (the only communication in
    the distributed version; built panel-free from (X, flat) by the
    ``impl``-selected backend with the lam-regularized diagonal fused in),
    then ``s`` local solves via block forward substitution, then deferred
    vector updates (Eqs. 9-10) from the same (X, flat) pair.

    ``guard`` arms the per-outer-step health word and degradation ladder
    (DESIGN.md section 7); ``fault`` threads a test-only
    :class:`repro.faults.FaultPlan`; ``step0`` offsets the outer-step
    numbering for segmented (checkpoint-resumed) solves.
    """
    plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles, track_cond=track_cond,
                      guard=guard, fault=fault)
    return s_step_solve(PRIMAL, plan, X, y, lam, iters, key, x0=w0, idx=idx,
                        w_ref=w_ref, step0=step0)


# ca_bcd at s=1 is classical bcd, so it is the canonical registry entry.
register_solver("primal", "local", ca_bcd)

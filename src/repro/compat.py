"""JAX version-compatibility shims.

The codebase targets the post-0.4.37 API surface (``jax.shard_map`` at top
level, explicit ``jax.sharding.AxisType`` on meshes, ``jax.lax.pvary`` for
varying-manual-axes bookkeeping).  The installed JAX may be 0.4.37, where none
of those exist: ``shard_map`` lives in ``jax.experimental.shard_map``, meshes
have no axis types, and replication tracking needs no pvary marks.

Everything mesh/shard_map-shaped in this repo goes through this module so the
same source runs on both API generations.  Keep the shims minimal and
feature-probed (``hasattr``), never version-string-parsed.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)
HAS_AXIS_TYPES = _AXIS_TYPE is not None


def _axis_types_kwargs(n_axes: int) -> dict[str, Any]:
    """``{'axis_types': (Auto,) * n}`` on JAX versions with explicit axis
    types (where shard_map requires Auto axes), ``{}`` on older ones."""
    if not HAS_AXIS_TYPES:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    kw: dict[str, Any] = _axis_types_kwargs(len(tuple(shape)))
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def device_mesh(devices, axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.sharding.Mesh`` from an explicit device ndarray (tests build
    shrunken / repeated-device meshes this way), axis types guarded."""
    axes = tuple(axes)
    return jax.sharding.Mesh(devices, axes, **_axis_types_kwargs(len(axes)))


def shard_map(f, *, mesh, in_specs, out_specs):
    """Top-level ``jax.shard_map`` when present, else the 0.4.x
    ``jax.experimental.shard_map`` (with replication checking off: the old
    checker cannot follow the solver scan carries that newer JAX handles via
    pvary, and the shims below make pvary a no-op there)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pvary(x, axis):
    """Mark a locally-created array as device-varying over ``axis`` -- vma
    bookkeeping for scan carries inside shard_map.  Old JAX (no pvary/pcast)
    does not track varying manual axes, so the mark is a no-op there."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to="varying")  # transitional spelling
    return x

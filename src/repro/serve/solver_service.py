"""Continuous-batching front end for the tenant-batched s-step engine.

The serving analogue of :class:`repro.serve.engine.Engine`, built on the
same :class:`~repro.serve.slots.SlotTable`: solve requests (a target ``y``,
an l2 weight ``lam``, optional formulation coefficients, a per-request
residual tolerance) queue into free slots, and every :meth:`step` advances
ALL live solves by one chunk of iterations through ONE
:func:`~repro.core.s_step_solve_batched` call -- one scan, one Gram packet
per outer step, shared by every tenant in the chunk.

Compile discipline mirrors the token engine's prompt buckets: the live
tenants are gathered into a power-of-two bucket (padded rows ride inactive,
masked to no-ops), and each ``(bucket, formulation)`` pair traces and
compiles exactly once -- a service processing thousands of requests touches
O(log slots) lowered shapes total.

Retirement is two-level, matching DESIGN.md section 8:

  * in-chunk: the engine's ``active0`` mask freezes tenants that were
    already retired, bit-exactly (a frozen tenant's carry is untouched);
  * between chunks: the host thresholds each tenant's ``residual`` metric
    against that REQUEST's own tolerance and frees the slot, so a converged
    solve stops consuming sweep work while its neighbors keep iterating.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SolverPlan, TenantBatch, batched_residuals,
                        s_step_solve_batched, sample_blocks)
from repro.core.engine import _resolve_form
from repro.serve.slots import SlotTable, bucket_pow2


@dataclasses.dataclass
class SolverServiceConfig:
    slots: int = 64             # table width == max concurrent tenants
    min_bucket: int = 8         # smallest compiled tenant bucket
    chunk_iters: int = 32       # iterations advanced per step()
    max_iters: int = 1024       # hard per-request cap (no-tol requests stop here)
    tol: float | None = None    # default per-request tolerance (None: run to cap)
    seed: int = 0               # block-index stream seed


@dataclasses.dataclass
class SolveTicket:
    """What a finished request leaves behind."""
    w: np.ndarray
    alpha: np.ndarray
    iters: int
    residual: float
    converged: bool             # True: hit its tolerance; False: iteration cap


class SolverService:
    """Slot-based many-tenant solve server over one shared operand ``X``."""

    def __init__(self, X: jax.Array, plan: SolverPlan,
                 formulation: str = "primal",
                 cfg: SolverServiceConfig | None = None):
        cfg = cfg or SolverServiceConfig()
        if cfg.min_bucket > cfg.slots:
            raise ValueError(
                f"min_bucket {cfg.min_bucket} exceeds slots {cfg.slots}")
        if plan.tenants is not None:
            raise ValueError(
                "SolverPlan.tenants is pinned by the service per bucket; "
                "pass a plan with tenants=None")
        self.X = X
        self.plan = plan
        self.formulation = formulation
        self.form = _resolve_form(formulation)
        self.cfg = cfg
        self.table = SlotTable(cfg.slots)
        d, n = X.shape
        self.d, self.n = d, n
        dt = X.dtype
        # Per-slot tenant state (numpy: host-mutable between chunks).
        self.ys = np.zeros((cfg.slots, n), dt)
        self.lams = np.ones((cfg.slots,), dt)
        self.coeffs: dict[str, np.ndarray] = {}
        self.ws = np.zeros((cfg.slots, d), dt)
        self.alphas = np.zeros((cfg.slots, n), dt)
        self.iters_run = np.zeros((cfg.slots,), np.int64)
        self.tols = np.full((cfg.slots,), np.inf)
        self._step = 0
        self._solve_cache: dict[tuple, object] = {}
        self._resid_cache: dict[int, object] = {}
        self._key = jax.random.PRNGKey(cfg.seed)

    # ------------------------------------------------------------- intake --
    def submit(self, y, lam: float, *, tol: float | None = None,
               **coeffs) -> int:
        """Queue one solve.  ``coeffs`` are per-tenant formulation fields
        (e.g. ``lam1=`` for the proximal); every request of one service must
        pass the same coefficient names, since they shape the compiled
        batch."""
        y = np.asarray(y, self.X.dtype)
        if y.shape != (self.n,):
            raise ValueError(f"y shape {y.shape} != ({self.n},)")
        if self.table.requests and set(coeffs) != set(self.coeffs):
            raise ValueError(
                f"coefficient names {sorted(coeffs)} differ from the "
                f"service's {sorted(self.coeffs)}; one compiled batch "
                "carries one coefficient set")
        for k in coeffs:
            if k not in self.coeffs:
                self.coeffs[k] = np.zeros((self.cfg.slots,), self.X.dtype)
        return self.table.submit(
            {"y": y, "lam": float(lam),
             "tol": self.cfg.tol if tol is None else float(tol),
             "coeffs": {k: float(v) for k, v in coeffs.items()}})

    # -------------------------------------------------------------- serve --
    def step(self) -> dict[int, SolveTicket]:
        """Admit queued requests, advance every live solve by one chunk,
        retire tenants that hit their tolerance or the iteration cap.
        Returns {rid: ticket} for requests finished this step."""
        for req in self.table.admit():
            s, p = req.slot, req.payload
            self.ys[s] = p["y"]
            self.lams[s] = p["lam"]
            self.tols[s] = np.inf if p["tol"] is None else p["tol"]
            for k in self.coeffs:
                self.coeffs[k][s] = p["coeffs"].get(k, 0.0)
            self.ws[s] = 0.0
            self.alphas[s] = 0.0
            self.iters_run[s] = 0
        live = self.table.active_slots()
        if not live:
            return {}

        bucket = bucket_pow2(len(live), self.cfg.min_bucket, self.cfg.slots)
        rows = (live + [live[0]] * (bucket - len(live)))[:bucket]
        active0 = np.zeros((bucket,), bool)
        active0[:len(live)] = True

        self._key, k = jax.random.split(self._key)
        idx = sample_blocks(k, self.form.sample_dim(self.d, self.n),
                            self.plan.b, self.cfg.chunk_iters)
        ws, alphas = self._chunk_fn(bucket)(
            jnp.asarray(self.ys[rows]), jnp.asarray(self.lams[rows]),
            {n_: jnp.asarray(v[rows]) for n_, v in self.coeffs.items()},
            (jnp.asarray(self.ws[rows]), jnp.asarray(self.alphas[rows])),
            jnp.asarray(active0), idx)
        ws, alphas = np.asarray(ws), np.asarray(alphas)
        self.ws[live] = ws[:len(live)]
        self.alphas[live] = alphas[:len(live)]
        self.iters_run[live] += self.cfg.chunk_iters

        resid = np.asarray(self._resid_fn(bucket)(
            jnp.asarray(self.ys[rows]), jnp.asarray(self.lams[rows]),
            {n_: jnp.asarray(v[rows]) for n_, v in self.coeffs.items()},
            (jnp.asarray(self.ws[rows]), jnp.asarray(self.alphas[rows]))))

        finished: dict[int, SolveTicket] = {}
        for i, s in enumerate(live):
            hit_tol = bool(np.isfinite(self.tols[s])
                           and resid[i] <= self.tols[s])
            capped = self.iters_run[s] >= self.cfg.max_iters
            if not (hit_tol or capped):
                continue
            req = self.table.retire(s)
            ticket = SolveTicket(
                w=self.ws[s].copy(), alpha=self.alphas[s].copy(),
                iters=int(self.iters_run[s]), residual=float(resid[i]),
                converged=hit_tol)
            req.out.append(ticket)
            finished[req.rid] = ticket
        self._step += 1
        return finished

    def serve(self, max_steps: int | None = None) -> dict[int, SolveTicket]:
        """Run :meth:`step` until the queue and table drain (or
        ``max_steps``).  Returns every ticket finished along the way."""
        done: dict[int, SolveTicket] = {}
        steps = 0
        while self.table.pending or self.table.any_active:
            if max_steps is not None and steps >= max_steps:
                break
            done.update(self.step())
            steps += 1
        return done

    def result(self, rid: int) -> SolveTicket | None:
        req = self.table.requests[rid]
        return req.out[-1] if req.done and req.out else None

    # ----------------------------------------------------------- compiled --
    def _chunk_fn(self, bucket: int):
        """One jitted chunk advance per (bucket, formulation): the compile
        cache the power-of-two padding exists to keep small."""
        key = (bucket, self.formulation, tuple(sorted(self.coeffs)))
        if key not in self._solve_cache:
            plan = dataclasses.replace(self.plan, tenants=bucket)
            chunk = self.cfg.chunk_iters

            def fn(ys, lams, coeffs, carry0, active0, idx):
                batch = TenantBatch(ys=ys, lams=lams, coeffs=coeffs)
                res = s_step_solve_batched(
                    self.formulation, plan, self.X, batch, chunk,
                    idx=idx, carry0=carry0, active0=active0)
                return res.ws, res.alphas

            self._solve_cache[key] = jax.jit(fn)
        return self._solve_cache[key]

    def _resid_fn(self, bucket: int):
        key = (bucket, self.formulation, tuple(sorted(self.coeffs)))
        if key not in self._resid_cache:
            def fn(ys, lams, coeffs, carries):
                return batched_residuals(
                    self.formulation, self.X,
                    TenantBatch(ys=ys, lams=lams, coeffs=coeffs), carries)
            self._resid_cache[key] = jax.jit(fn)
        return self._resid_cache[key]

"""Slot-table continuous batching: the bookkeeping shared by every serving
front end in this repo.

Two engines batch very different payloads over the same skeleton:

  * :class:`~repro.serve.engine.Engine` decodes tokens -- a slot owns a KV /
    state-cache stripe,
  * :class:`~repro.serve.solver_service.SolverService` advances s-step
    solves -- a slot owns a tenant's (w, alpha) carry row,

and both need exactly this machinery: a FIFO admission queue, a fixed-width
table of slots each bound to at most one live request, and power-of-two
bucketing so the number of distinct compiled shapes stays logarithmic in the
width being padded.  The domain state (caches, carries, positions) stays in
the engine; the table only tracks which request sits where.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def bucket_pow2(n: int, min_bucket: int, cap: int) -> int:
    """Smallest power-of-two >= ``n``, floored at ``min_bucket`` and clipped
    to ``cap``.  Each bucket value is a compile-cache key: padding work up to
    a bucket trades a bounded amount of wasted compute for O(log) distinct
    lowered shapes instead of one per request size."""
    if n < 0:
        raise ValueError(f"bucket_pow2: negative size {n}")
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, cap)


@dataclasses.dataclass
class SlotRequest:
    """One queued or running request.  ``payload`` is the engine's input
    (prompt tokens, solver right-hand side...), ``out`` accumulates the
    engine's output, ``slot`` is -1 until admitted."""
    rid: int
    payload: object
    out: list
    slot: int = -1
    done: bool = False


class SlotTable:
    """Fixed-width slot table + FIFO queue.

    The lifecycle every engine shares: ``submit`` enqueues, ``admit`` moves
    queued requests into free slots (the engine installs its domain state
    per admission), ``retire`` frees a slot and marks the request done.
    ``active`` is a numpy bool mask over slots -- engines ship it (or a
    gathered view) to the device as their no-op mask.
    """

    def __init__(self, slots: int):
        if slots <= 0:
            raise ValueError(f"SlotTable needs >= 1 slot, got {slots}")
        self.slots = slots
        self.active = np.zeros((slots,), bool)
        self.slot_req: list[int | None] = [None] * slots
        self.queue: list[SlotRequest] = []
        self.requests: dict[int, SlotRequest] = {}
        self._next_rid = 0

    # ------------------------------------------------------------- intake --
    def submit(self, payload) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = SlotRequest(rid, payload, [])
        self.queue.append(req)
        self.requests[rid] = req
        return rid

    def admit(self) -> list[SlotRequest]:
        """Move queued requests into free slots (FIFO x first-free), mark
        them active, and return the newly admitted requests so the caller
        can install its per-slot domain state (prefill a cache stripe, seed
        a solver carry row...)."""
        admitted = []
        for s in range(self.slots):
            if self.active[s] or not self.queue:
                continue
            req = self.queue.pop(0)
            req.slot = s
            self.slot_req[s] = req.rid
            self.active[s] = True
            admitted.append(req)
        return admitted

    # ----------------------------------------------------------- teardown --
    def retire(self, slot: int) -> SlotRequest | None:
        """Free ``slot``; returns the request that occupied it (now done)."""
        rid = self.slot_req[slot]
        req = None
        if rid is not None:
            req = self.requests[rid]
            req.done = True
        self.active[slot] = False
        self.slot_req[slot] = None
        return req

    # -------------------------------------------------------------- views --
    def request_in(self, slot: int) -> SlotRequest:
        rid = self.slot_req[slot]
        if rid is None:
            raise KeyError(f"slot {slot} is empty")
        return self.requests[rid]

    def active_slots(self) -> list[int]:
        return [s for s in range(self.slots) if self.active[s]]

    @property
    def any_active(self) -> bool:
        return bool(self.active.any())

    @property
    def pending(self) -> int:
        return len(self.queue)

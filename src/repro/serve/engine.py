"""Batched serving engine: slot-based continuous batching.

Production shape (vLLM-style, sized down to this framework's scope):
  * fixed decode batch of ``slots``; each slot owns a stripe of every cache
    leaf (slot axis = axis 1; axis 0 is the scanned layer stack),
  * prompts are prefetched into free slots by a bucketed prefill (prompt
    lengths padded to a power-of-two bucket so each bucket compiles once;
    right padding is safe because decode masks keys at positions > pos),
  * every engine.step() decodes ALL slots in one jit'd call (inactive slots
    compute garbage that is never read -- the fixed-shape SPMD trade),
  * greedy or temperature sampling, EOS + max-len retirement.

serve_step == decode_step is exactly what the decode_32k / long_500k dry-run
cells lower at the production mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, init_params
from repro.serve.slots import SlotTable, bucket_pow2


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    slots: int = 4
    temperature: float = 0.0
    eos_id: int | None = None
    seed: int = 0
    min_bucket: int = 32


class Engine:
    def __init__(self, model_cfg, params, cfg: ServeConfig):
        self.mc = model_cfg
        self.cfg = cfg
        self.params = params
        cache_specs = api.init_cache_specs(model_cfg, cfg.slots, cfg.max_seq)
        self.cache = init_params(cache_specs, jax.random.key(0))  # zeros
        self.pos = np.zeros((cfg.slots,), np.int32)       # next write position
        self.table = SlotTable(cfg.slots)
        self._key = jax.random.key(cfg.seed)

        self._decode = jax.jit(self._decode_impl, donate_argnums=1)
        self._prefill_cache = {}

    # Slot bookkeeping lives in the shared table; these views keep the
    # engine's original surface (tests and the dry-run cells poke them).
    @property
    def active(self):
        return self.table.active

    @property
    def slot_req(self):
        return self.table.slot_req

    @property
    def queue(self):
        return self.table.queue

    @property
    def requests(self):
        return self.table.requests

    # ------------------------------------------------------------ public --
    def add_request(self, prompt_tokens) -> int:
        prompt_tokens = list(map(int, prompt_tokens))
        if self.mc.family in ("ssm", "hybrid"):
            # SSM recurrences are not mask-protected: right padding would
            # pollute conv/ssm states.  Standard chunked-prefill constraint:
            # prompts must align to the SSD chunk so prefill runs unpadded.
            chunk = self.mc.ssm.chunk
            if len(prompt_tokens) % chunk:
                raise ValueError(
                    f"{self.mc.name}: prompt length {len(prompt_tokens)} must "
                    f"be a multiple of the SSD chunk ({chunk}) -- align or "
                    f"truncate the prompt (chunked-prefill constraint)")
        return self.table.submit(prompt_tokens)

    def step(self) -> dict[int, int]:
        """Admit queued requests, decode one token for all active slots.
        Returns {rid: new_token} for slots that produced a token."""
        self._admit()
        if not self.active.any():
            return {}
        tok = np.zeros((self.cfg.slots,), np.int32)
        for s in self.table.active_slots():
            req = self.table.request_in(s)
            tok[s] = (req.out[-1] if req.out else req.payload[-1])
        self._key, k = jax.random.split(self._key)
        logits, self.cache, sampled = self._decode(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(self.pos), k)
        sampled = np.asarray(sampled)
        out = {}
        for s in self.table.active_slots():
            t = int(sampled[s])
            req = self.table.request_in(s)
            req.out.append(t)
            out[req.rid] = t
            self.pos[s] += 1
            if ((self.cfg.eos_id is not None and t == self.cfg.eos_id)
                    or self.pos[s] >= self.cfg.max_seq):
                self._retire(s)
        return out

    def generate(self, prompts, max_new: int) -> list[list[int]]:
        rids = [self.add_request(p) for p in prompts]
        budget = {r: max_new for r in rids}
        while any(not self.requests[r].done and budget[r] > 0 for r in rids):
            produced = self.step()
            for r, _ in produced.items():
                if r in budget:
                    budget[r] -= 1
                    if budget[r] == 0 and not self.requests[r].done:
                        self._retire(self.requests[r].slot)
            if not produced and not self.queue:
                break
        return [self.requests[r].out for r in rids]

    # ----------------------------------------------------------- internal --
    def _decode_impl(self, params, cache, tok, pos, key):
        logits, cache = api.decode_step(params, self.mc, cache, tok, pos)
        logits = logits[:, :self.mc.vocab]           # mask vocab padding
        if self.cfg.temperature > 0:
            sampled = jax.random.categorical(
                key, logits / self.cfg.temperature, axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        return logits, cache, sampled.astype(jnp.int32)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            def fn(params, tokens, last_pos):
                batch = {"tokens": tokens}
                logits, cache = api.prefill(params, self.mc, batch,
                                            max_seq=self.cfg.max_seq)
                return logits, cache
            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    def _admit(self) -> None:
        for req in self.table.admit():
            s = req.slot
            plen = len(req.payload)
            # ssm/hybrid: exact (chunk-aligned) prefill; attention: padded
            # power-of-two bucket (padding is attention-mask safe).
            bucket = plen if self.mc.family in ("ssm", "hybrid") \
                else bucket_pow2(plen, self.cfg.min_bucket, self.cfg.max_seq)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = req.payload[:bucket]
            logits, cache1 = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks), jnp.asarray([plen - 1]))
            # copy the single-request cache stripe into slot s (axis 1:
            # axis 0 is the scanned layer stack).
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, s].set(one[:, 0]),
                self.cache, cache1)
            # first generated token comes from the prefill logits at the last
            # *real* prompt position: with right padding that is plen-1 ==
            # bucket-1 only when plen == bucket, so decode re-scores from the
            # last prompt token instead of trusting padded prefill logits.
            self.pos[s] = plen - 1
            # replay the last prompt token through decode to get clean logits
            # at position plen-1 (also refreshes that cache row).
            req.out = []

    def _retire(self, slot: int) -> None:
        self.table.retire(slot)
        self.pos[slot] = 0

"""AdamW with mixed precision and ZeRO-1 sharded optimizer state.

Production layout (DESIGN.md section 3):
  * model params live in cfg.param_dtype (bf16 for the big archs),
  * the optimizer owns an f32 master copy + f32 (m, v), all sharded with the
    ZeRO rule set (fsdp=True: the non-TP dim spreads over 'data'), so the
    >100B archs fit: 398B x 16B/param would be 6.2 TB replicated, vs
    ~24 GB/chip sharded 256-way,
  * gradients arrive in bf16 (the "gradient compression" knob: the DP
    all-reduce moves half the bytes of f32; measured in the roofline table)
    and are upcast exactly once for the f32 update.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec, tree_map_specs


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)


def opt_state_specs(param_specs_tree) -> dict:
    """ParamSpec tree for (master, m, v): f32, same logical axes as params.
    The sharding layer applies the ZeRO rules to these."""
    def f32_spec(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, jnp.float32, init="zeros")

    def master_spec(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, jnp.float32, init=s.init, scale=s.scale)

    return {
        "master": tree_map_specs(master_spec, param_specs_tree),
        "m": tree_map_specs(f32_spec, param_specs_tree),
        "v": tree_map_specs(f32_spec, param_specs_tree),
    }


def init_opt_state(params) -> dict:
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def _global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, opt_state, step, cfg: AdamWConfig):
    """One AdamW step.  Returns (new params in the original param dtype,
    new opt state, metrics)."""
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = cfg.lr_at(step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, master, m, v):
        g32 = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return master.astype(p.dtype), master, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_ma = jax.tree.leaves(opt_state["master"])
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    outs = [upd(*args) for args in zip(flat_p, flat_g, flat_ma, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "master": jax.tree.unflatten(treedef, [o[1] for o in outs]),
        "m": jax.tree.unflatten(treedef, [o[2] for o in outs]),
        "v": jax.tree.unflatten(treedef, [o[3] for o in outs]),
    }
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}

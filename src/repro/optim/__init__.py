from .adamw import (AdamWConfig, adamw_update, init_opt_state,
                    opt_state_specs)
from .schedules import cosine_warmup

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "opt_state_specs",
           "cosine_warmup"]

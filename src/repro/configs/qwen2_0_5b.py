"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias. [arXiv:2407.10671; hf]  14 heads / 2 kv heads do not divide TP=16
-> attention replicated over 'model' (guarded)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151936, head_dim=64,
    qkv_bias=True, rope_theta=1000000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-0.5b-reduced", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab=256, head_dim=32,
        block_q=64, block_kv=64, remat="none")

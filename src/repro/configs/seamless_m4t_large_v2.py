"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 (padded 256256).
[arXiv:2308.11596; hf]  Frontend is a STUB per the assignment: input_specs
provides precomputed audio frame embeddings (B, S_enc, D); S_enc = seq_len/4
(conv-subsampled frame rate, documented in EXPERIMENTS.md)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24, enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
    head_dim=64, frontend="audio", rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-m4t-large-v2-reduced", n_layers=2, enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        block_q=64, block_kv=64, remat="none")

"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 (padded to 49408 for TP; Megatron-style).
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, head_dim=64,
    rope_theta=10000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite-3-2b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=250, head_dim=16,
        block_q=64, block_kv=64, remat="none")

"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752, MoE 16e top-4
(fine-grained), vocab=100352.  [hf:databricks/dbrx-base; unverified]
fsdp=True: 132B params need data-axis parameter sharding."""
import dataclasses
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=10752, vocab=100352, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=4), rope_theta=500000.0, fsdp=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="dbrx-132b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0),
        block_q=64, block_kv=64, remat="none", fsdp=False)

"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B family; unverified]  24 q-heads do not divide
TP=16 -> attention weights replicate over 'model' (guarded rule; see
DESIGN.md section 4); MLP/vocab are TP-sharded."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=128,
    rope_theta=500000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama3.2-3b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        block_q=64, block_kv=64, remat="none")

"""mamba2-370m [ssm]: 48L d_model=1024, attention-free SSD, ssm_state=128,
vocab=50280 (padded 50432). [arXiv:2405.21060; unverified]  Sub-quadratic:
runs the long_500k cell (decode state is O(1) per token)."""
import dataclasses
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    subquadratic=True, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-370m-reduced", n_layers=2, d_model=64,
        vocab=256, ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                                 chunk=32), remat="none")

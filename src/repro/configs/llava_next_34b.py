"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 backbone; anyres tiling -> 2880 patch embeddings prefix
(5 tiles x 576), provided precomputed by the stub frontend per the
assignment.  [hf:llava-hf family; unverified]  56 heads do not divide TP=16
-> attention replicated over 'model' (guarded; see section Perf hillclimb for
the 8-way alternative)."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
    frontend="vision", frontend_tokens=2880, rope_theta=5000000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-next-34b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        frontend_tokens=16, block_q=64, block_kv=64, remat="none")

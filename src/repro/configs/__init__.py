"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (published geometry, source cited in the file) and
reduced() (CPU-smoke miniature of the same family).
"""
from __future__ import annotations

import importlib

from .base import (ModelConfig, MoEConfig, ShapeConfig, SSMConfig, SHAPES,
                   n_active_params, n_params, pad_vocab)

ARCH_IDS = [
    "llama3_2_3b",
    "mistral_nemo_12b",
    "qwen2_0_5b",
    "granite_3_2b",
    "mamba2_370m",
    "seamless_m4t_large_v2",
    "jamba_1_5_large_398b",
    "dbrx_132b",
    "phi3_5_moe_42b",
    "llava_next_34b",
]

# public --arch aliases (hyphenated, as in the assignment) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "llama3.2-3b": "llama3_2_3b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-3-2b": "granite_3_2b",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "dbrx-132b": "dbrx_132b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "phi3.5-moe-42b": "phi3_5_moe_42b",
    "llava-next-34b": "llava_next_34b",
})


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "ARCH_IDS", "ALIASES", "get_config", "get_reduced", "n_params",
           "n_active_params", "pad_vocab"]

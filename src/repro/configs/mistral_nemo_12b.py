"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, head_dim=128, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mistral-nemo-12b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab=256, head_dim=16,
        block_q=64, block_kv=64, remat="none")

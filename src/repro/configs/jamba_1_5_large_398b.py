"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 on alternating layers, Mamba:attn 7:1 interleave
(attention at position 4 of each 8-layer period).  [arXiv:2403.19887; hf]
Sub-quadratic overall (KV cache only on 9 of 72 layers) -> runs long_500k.
fsdp=True: 398B params exceed per-chip HBM under pure TP."""
import dataclasses
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, every_n_layers=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk=128),
    attn_layer_period=8, subquadratic=True, fsdp=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-1.5-large-398b-reduced", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, every_n_layers=2,
                      capacity_factor=4.0),  # no-drop for exactness tests
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        block_q=64, block_kv=64, remat="none", fsdp=False)

"""Config system: one ModelConfig per assigned architecture plus the shape
suite (train_4k / prefill_32k / decode_32k / long_500k).

Every config file exports ``CONFIG`` (the exact published geometry) and
``reduced()`` (a same-family miniature for CPU smoke tests).  The registry in
``repro.configs`` resolves ``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

VOCAB_PAD_MULTIPLE = 256  # Megatron-style vocab padding for clean TP


def pad_vocab(v: int, mult: int = VOCAB_PAD_MULTIPLE) -> int:
    return -(-v // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    every_n_layers: int = 1      # MoE replaces MLP on layers where i % n == n-1
    aux_loss_weight: float = 0.01
    groups: int = 1              # GShard-style dispatch groups: routing/sort/
                                 # capacity run per group (group dim follows the
                                 # batch sharding => no cross-shard sort traffic)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | audio | hybrid | moe | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_layer_period: int = 1     # hybrid: 1 attn layer per this many (jamba: 8)
    enc_layers: int = 0            # enc-dec: encoder depth (seamless)
    frontend: str = "none"         # none | audio | vision (stub embedders)
    frontend_tokens: int = 0       # patches/frames occupying the prefix
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16      # activation dtype
    param_dtype: Any = jnp.bfloat16
    remat: str = "full"            # full | dots | none
    fsdp: bool = False             # shard params over data axis (ZeRO-3-ish)
    # attention chunking (flash-style pure-JAX attention)
    block_q: int = 512
    block_kv: int = 1024
    scan_unroll: int = 1   # dry-run cost-probe: unroll layer scans for exact HLO counts
    ssd_unroll: int = 1    # dry-run cost-probe: unroll the SSD chunk scan
    subquadratic: bool = False     # eligible for long_500k
    q_head_pad: int = 0            # extra (zero-output) q heads per kv group:
                                   # pads H to a TP-divisible count (sec Perf)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_q_heads(self) -> int:
        return self.n_heads + self.n_kv_heads * self.q_head_pad

    def layer_kind(self, i: int) -> str:
        """attn | mamba for layer i (hybrid interleave; jamba puts the attn
        layer mid-period)."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_layer_period > 1:
            return "attn" if i % self.attn_layer_period == self.attn_layer_period // 2 \
                else "mamba"
        return "attn"

    def mlp_kind(self, i: int) -> str:
        if self.moe and i % self.moe.every_n_layers == self.moe.every_n_layers - 1:
            return "moe"
        return "dense"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def applicable(self, cfg: ModelConfig) -> tuple[bool, str]:
        if self.name == "long_500k" and not cfg.subquadratic:
            return False, "full-attention arch: O(S^2) at 512k infeasible (DESIGN.md section 4)"
        return True, ""


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def n_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (cross-checked against ParamSpec trees in tests)."""
    from repro.models import api  # local import to avoid cycles
    from repro.models.module import param_count
    return param_count(api.param_specs(cfg))


def n_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of num_experts expert sets)."""
    total = n_params(cfg)
    if not cfg.moe:
        return total
    from repro.models import api
    from repro.models.module import param_count
    expert_params = param_count(api.param_specs(cfg, experts_only=True))
    active = total - expert_params + expert_params * cfg.moe.top_k // cfg.moe.num_experts
    return active

"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
MoE 16e top-2, vocab=32064 (padded 32256). [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import dataclasses
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2), rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi3.5-moe-42b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0),
        block_q=64, block_kv=64, remat="none")

"""Deterministic, resumable, host-shardable synthetic LM token pipeline.

Design goals of a production input pipeline that matter even with synthetic
data (and are all tested):

* **Determinism / exact resume** -- batches are a pure function of
  (seed, step) via the counter-based Philox generator, so checkpointing the
  integer ``step`` is sufficient to resume the exact stream.  No iterator
  state can drift across restarts or host failures.
* **Host sharding** -- each host materializes only its ``1/num_hosts`` slice
  of the global batch (disjoint Philox streams per host), the standard
  multi-pod input layout.
* **Learnability** -- tokens follow a noisy affine next-token process
  ``t_{k+1} = (a * t_k + c) mod V`` so end-to-end training loss demonstrably
  falls (examples/train_lm.py); pure-uniform streams cannot show that.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    noise: float = 0.05           # fraction of positions replaced with uniform noise
    step: int = 0                 # resumable cursor

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.local_batch = self.global_batch // self.num_hosts
        # affine map parameters; co-prime-ish with vocab for long cycles
        self._a = 6364136223846793005 % max(self.vocab - 3, 2) | 1
        self._c = 1442695040888963407 % max(self.vocab - 3, 2)

    def _rng(self, step: int) -> np.random.Generator:
        # 128-bit Philox key: (seed | host) and step -- a pure counter scheme.
        return np.random.Generator(np.random.Philox(
            key=[(self.seed << 20) ^ self.host_index, step]))

    def batch_at(self, step: int) -> dict:
        """Pure function of step -> {tokens (B_local, S+1) int32}."""
        rng = self._rng(step)
        v = self.vocab
        b, s = self.local_batch, self.seq_len + 1
        t0 = rng.integers(0, v, size=(b, 1), dtype=np.int64)
        toks = np.empty((b, s), dtype=np.int64)
        toks[:, 0] = t0[:, 0]
        for k in range(1, s):
            toks[:, k] = (toks[:, k - 1] * self._a + self._c) % v
        noise_mask = rng.random((b, s)) < self.noise
        noise_tok = rng.integers(0, v, size=(b, s), dtype=np.int64)
        toks = np.where(noise_mask, noise_tok, toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((b, self.seq_len), np.float32),
        }

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    # -- checkpoint integration -------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed,
                "host_index": self.host_index, "num_hosts": self.num_hosts}

    def load_state_dict(self, state: dict) -> None:
        if int(state["seed"]) != self.seed:
            raise ValueError("resuming a stream with a different seed")
        self.step = int(state["step"])


def synthetic_lm_batch(vocab: int, seq_len: int, batch: int, seed: int = 0) -> dict:
    """One-shot batch helper for tests and smoke runs."""
    return TokenStream(vocab, seq_len, batch, seed=seed).batch_at(0)

from .regression import SyntheticSpec, make_regression, PAPER_DATASETS
from .tokens import TokenStream, synthetic_lm_batch

__all__ = ["SyntheticSpec", "make_regression", "PAPER_DATASETS",
           "TokenStream", "synthetic_lm_batch"]

"""Synthetic regularized-least-squares problems with controlled spectra.

The container is offline, so the paper's LIBSVM datasets (Table 3) are replaced
by generators matched in shape and conditioning.  X = U diag(sigma) V^T with
Haar-ish orthogonal factors (QR of Gaussians) and a log-linear singular value
ramp from sigma_max down to sigma_min, plus optional sparsity to mimic nnz%.
The labels are y = X^T w_star + noise so the problem has a meaningful signal.

Conclusions drawn from these problems are the paper's *relative* claims
(CA == classical convergence, latency / s, b/s trade-off shapes), which depend
on shape and conditioning, not on dataset identity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    d: int                 # features (rows of X)
    n: int                 # data points (columns of X)
    cond: float            # sigma_max / sigma_min of X^T X
    noise: float = 1e-2
    density: float = 1.0   # fraction of entries kept (0 < density <= 1)


# Shape/conditioning stand-ins for Table 3 (scaled down ~8-32x so the full
# figure-sweep benchmarks run in CPU minutes; aspect ratios and condition
# numbers of X^T X are preserved).
PAPER_DATASETS = {
    "abalone": SyntheticSpec("abalone", d=8, n=4177, cond=5.3e8),
    "news20": SyntheticSpec("news20", d=7757, n=1991, cond=3.5e11, density=0.0013),
    "a9a": SyntheticSpec("a9a", d=123, n=4069, cond=4.1e10, density=0.11),
    "real-sim": SyntheticSpec("real-sim", d=2619, n=9038, cond=8.4e5, density=0.0024),
}


def make_regression(key: jax.Array, spec: SyntheticSpec, dtype=jnp.float64):
    """Returns (X (d,n), y (n,), w_star (d,)).

    The singular values of X are spaced geometrically so that
    cond(X^T X) = spec.cond (i.e. sigma ramp spans sqrt(cond)).
    """
    d, n = spec.d, spec.n
    r = min(d, n)
    k_u, k_v, k_s, k_w, k_e, k_m = jax.random.split(key, 6)
    U, _ = jnp.linalg.qr(jax.random.normal(k_u, (d, r), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(k_v, (n, r), dtype))
    # sqrt(cond) ramp on X's singular values => cond on the Gram spectrum.
    ramp = jnp.logspace(0.0, -0.5 * jnp.log10(jnp.asarray(spec.cond, dtype)), r,
                        dtype=dtype)
    X = (U * ramp) @ V.T
    if spec.density < 1.0:
        mask = jax.random.bernoulli(k_m, spec.density, X.shape)
        X = jnp.where(mask, X / spec.density, 0.0).astype(dtype)
    w_star = jax.random.normal(k_w, (d,), dtype)
    y = X.T @ w_star
    y = y + spec.noise * jnp.linalg.norm(y) / jnp.sqrt(n) * jax.random.normal(k_e, (n,), dtype)
    return X, y, w_star


def lam_for(X: jax.Array, scale: float = 1000.0) -> jax.Array:
    """The paper's regularizer choice: lambda = 1000 * sigma_min(X^T X)."""
    d, n = X.shape
    G = X @ X.T if d <= n else X.T @ X
    evs = jnp.linalg.eigvalsh(G)
    return scale * jnp.clip(evs[0], 1e-30, None)

"""Model assembly: every assigned architecture reduces to one of three bodies

  * decoder  -- dense / moe / ssm / hybrid / vlm (llava = decoder + patch
                prefix; mamba2 = decoder with mamba sublayers and no MLP;
                jamba = 1:7 attn:mamba interleave + alternating MoE)
  * encdec   -- seamless (audio encoder + cross-attending text decoder)

assembled from ParamSpec trees and scanned superblocks.  The *superblock* is
the lcm of the attention interleave period and the MoE period, so every arch
is a homogeneous scan over superblocks (compile cost = one superblock body).

Public entry points (used by the trainer, server, dry-run and tests):
  param_specs(cfg)                      -> ParamSpec tree
  forward(params, cfg, batch)           -> logits
  loss_fn(params, cfg, batch)           -> (loss, metrics)
  init_cache_specs(cfg, batch, max_seq) -> cache ParamSpec-like tree
  prefill(params, cfg, tokens, ...)     -> (logits_last, cache)
  decode_step(params, cfg, cache, ...)  -> (logits, new cache)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as M
from . import moe as MOE
from .module import ParamSpec, stack_specs

# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def _superblock_period(cfg) -> int:
    period = cfg.attn_layer_period
    if cfg.moe:
        period = math.lcm(period, cfg.moe.every_n_layers)
    return period


def _sublayer_specs(cfg, i: int) -> dict:
    specs: dict = {"ln1": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype)}
    if cfg.layer_kind(i) == "attn":
        specs["attn"] = L.attention_specs(cfg)
    else:
        specs["mamba"] = M.mamba_specs(cfg)
    if cfg.d_ff > 0:
        specs["ln2"] = L.rmsnorm_spec(cfg.d_model, cfg.param_dtype)
        if cfg.mlp_kind(i) == "moe":
            specs["moe"] = MOE.moe_specs(cfg)
        else:
            specs["mlp"] = L.mlp_specs(cfg)
    return specs


def _block_specs(cfg) -> dict:
    period = _superblock_period(cfg)
    if cfg.n_layers % period:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} not divisible "
                         f"by superblock period {period}")
    sub = {f"sub{j}": _sublayer_specs(cfg, j) for j in range(period)}
    return stack_specs(sub, cfg.n_layers // period)


def _encdec_specs(cfg) -> dict:
    # Encoder: bidirectional attn + MLP; decoder: self-attn + cross-attn + MLP.
    enc_layer = {
        "ln1": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": L.attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": L.mlp_specs(cfg),
    }
    dec_layer = {
        "ln1": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": L.attention_specs(cfg),
        "lnx": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "cross": L.attention_specs(cfg, cross=True),
        "ln2": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": L.mlp_specs(cfg),
    }
    return {
        "encoder": stack_specs(enc_layer, cfg.enc_layers),
        "enc_norm": L.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "decoder": stack_specs(dec_layer, cfg.n_layers),
    }


def param_specs(cfg, experts_only: bool = False) -> dict:
    if experts_only:
        if not cfg.moe:
            return {}
        moe_layers = cfg.n_layers // cfg.moe.every_n_layers
        e = MOE.moe_specs(cfg)
        return stack_specs({k: e[k] for k in ("w1", "w2", "w3")}, moe_layers)
    specs: dict = dict(L.embed_specs(cfg))
    specs["final_norm"] = L.rmsnorm_spec(cfg.d_model, cfg.param_dtype)
    if cfg.family == "audio":
        specs.update(_encdec_specs(cfg))
    else:
        specs["blocks"] = _block_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# Sublayer application
# ---------------------------------------------------------------------------


def _apply_attn(p, x, cfg, positions, *, causal=True, x_kv=None):
    q, k, v = L.qkv_proj(p, x, x_kv)
    if x_kv is None:  # self-attention: rope on q and k
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    if cfg.q_head_pad:
        # head-padding layout (section Perf): q was padded per kv group to a
        # TP-divisible count; repeat kv to match so every head dim shards
        # cleanly (repeated kv == grouped GQA math, exactly).
        g = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    out = L.chunked_attention(q, k, v, causal=causal,
                              block_q=cfg.block_q, block_kv=cfg.block_kv)
    return L.out_proj(p, out)


def _apply_sublayer(lp, x, cfg, j, positions, aux):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if "attn" in lp:
        x = x + _apply_attn(lp["attn"], h, cfg, positions)
    else:
        x = x + M.mamba_block(lp["mamba"], h, cfg)
    if "ln2" in lp:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            out, metrics = MOE.moe_block(lp["moe"], h, cfg)
            aux = {k: aux.get(k, 0.0) + v for k, v in metrics.items()}
            x = x + out
        else:
            x = x + L.swiglu(lp["mlp"], h)
    return x, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _decoder_stack(params, cfg, x, positions):
    period = _superblock_period(cfg)

    def block(carry, blk):
        x, aux = carry
        for j in range(period):
            x, aux = _apply_sublayer(blk[f"sub{j}"], x, cfg, j, positions, aux)
        return (x, aux), None

    aux0 = ({"moe_aux_loss": jnp.float32(0), "moe_drop_frac": jnp.float32(0)}
            if cfg.moe else {})
    (x, aux), _ = jax.lax.scan(_remat(block, cfg), (x, aux0), params["blocks"],
                               unroll=cfg.scan_unroll)
    return x, aux


def _encoder_stack(params, cfg, x, positions):
    def block(carry, lp):
        x = carry
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + _apply_attn(lp["attn"], h, cfg, positions, causal=False)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(_remat(block, cfg), x, params["encoder"],
                        unroll=cfg.scan_unroll)
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_decoder_stack(params, cfg, x, positions, enc_out):
    def block(carry, lp):
        x, aux = carry
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + _apply_attn(lp["attn"], h, cfg, positions)
        h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        x = x + _apply_attn(lp["cross"], h, cfg, positions, causal=False,
                            x_kv=enc_out)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(_remat(block, cfg), (x, {}), params["decoder"],
                               unroll=cfg.scan_unroll)
    return x, aux


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def forward(params, cfg, batch: dict):
    """Returns (logits (B, S, Vpad), aux metrics).  batch keys:
    tokens (B, St); optional extra_embeds (B, Sx, D) prefixed (vlm/audio-as-
    decoder); audio family instead uses src_embeds + tokens."""
    if cfg.family == "audio":
        positions_src = jnp.arange(batch["src_embeds"].shape[1])[None, :]
        enc = _encoder_stack(params, cfg, batch["src_embeds"].astype(cfg.dtype),
                             positions_src)
        x = L.embed(params, batch["tokens"]).astype(cfg.dtype)
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux = _cross_decoder_stack(params, cfg, x, positions, enc)
    else:
        x = L.embed(params, batch["tokens"]).astype(cfg.dtype)
        extra = batch.get("extra_embeds")
        if extra is not None:
            x = jnp.concatenate([extra.astype(cfg.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux = _decoder_stack(params, cfg, x, positions)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, x)
    return logits, aux


def loss_fn(params, cfg, batch: dict):
    """Next-token cross entropy in f32 with masking; adds MoE aux losses."""
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("mask")
    St = labels.shape[1]
    logits = logits[:, -St:, :].astype(jnp.float32)          # text positions only
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1)
    metrics = {"loss": loss, "ppl_log": loss}
    total = loss
    if aux.get("moe_aux_loss") is not None and cfg.moe:
        total = total + aux["moe_aux_loss"] / max(cfg.n_layers // cfg.moe.every_n_layers, 1)
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
    return total, metrics


# ---------------------------------------------------------------------------
# KV / state caches and decode
# ---------------------------------------------------------------------------


def _cache_sublayer_specs(cfg, i: int, batch: int, max_seq: int) -> dict:
    if cfg.layer_kind(i) == "attn":
        hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        shape = (batch, max_seq, hkv, dh)
        axes = ("batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": ParamSpec(shape, axes, cfg.dtype, init="zeros"),
                "v": ParamSpec(shape, axes, cfg.dtype, init="zeros")}
    s = cfg.ssm
    di, h, gn = s.d_inner(cfg.d_model), s.n_heads(cfg.d_model), s.n_groups * s.d_state
    return {
        "ssm": ParamSpec((batch, h, s.head_dim, s.d_state),
                         ("batch", "inner", "head_dim", "state"), jnp.float32,
                         init="zeros"),
        "conv_x": ParamSpec((batch, s.d_conv - 1, di),
                            ("batch", "conv", "inner"), cfg.dtype, init="zeros"),
        "conv_B": ParamSpec((batch, s.d_conv - 1, gn),
                            ("batch", "conv", "state"), cfg.dtype, init="zeros"),
        "conv_C": ParamSpec((batch, s.d_conv - 1, gn),
                            ("batch", "conv", "state"), cfg.dtype, init="zeros"),
    }


def init_cache_specs(cfg, batch: int, max_seq: int) -> dict:
    """ParamSpec tree for the decode cache (abstract-init'able for dry-run)."""
    if cfg.family == "audio":
        hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        self_shape = (batch, max_seq, hkv, dh)
        enc_len = max(max_seq // 4, 128)
        cross_shape = (batch, enc_len, hkv, dh)
        axes = ("batch", "cache_seq", "kv_heads", "head_dim")
        layer = {"k": ParamSpec(self_shape, axes, cfg.dtype, init="zeros"),
                 "v": ParamSpec(self_shape, axes, cfg.dtype, init="zeros"),
                 "xk": ParamSpec(cross_shape, axes, cfg.dtype, init="zeros"),
                 "xv": ParamSpec(cross_shape, axes, cfg.dtype, init="zeros")}
        return {"decoder": stack_specs(layer, cfg.n_layers)}
    period = _superblock_period(cfg)
    sub = {f"sub{j}": _cache_sublayer_specs(cfg, j, batch, max_seq)
           for j in range(period)}
    return {"blocks": stack_specs(sub, cfg.n_layers // period)}


def _decode_attn_sublayer(lp, cache, x, cfg, pos):
    """x (B, 1, D); cache {k, v} (B, Smax, Hkv, Dh); pos (B,) int32."""
    B = x.shape[0]
    q, k, v = L.qkv_proj(lp, x)
    q = L.rope(q, pos[:, None], cfg.rope_theta)
    k = L.rope(k, pos[:, None], cfg.rope_theta)
    ck = cache["k"].at[jnp.arange(B), pos].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[jnp.arange(B), pos].set(v[:, 0].astype(cache["v"].dtype))
    out = L.decode_attention(q, ck, cv, pos)
    return L.out_proj(lp, out), {"k": ck, "v": cv}


def decode_step(params, cfg, cache, token, pos):
    """One decode step.  token (B,) int32, pos (B,) int32 current positions.
    Returns (logits (B, Vpad), new cache)."""
    x = L.embed(params, token[:, None]).astype(cfg.dtype)    # (B, 1, D)

    if cfg.family == "audio":
        def block(x, xs):
            lp, c = xs
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            attn_out, new_c = _decode_attn_sublayer(lp["attn"], c, h, cfg, pos)
            x = x + attn_out
            h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
            q, _, _ = L.qkv_proj(lp["cross"], h)             # cross k/v cached
            enc_len = c["xk"].shape[1]
            out = L.decode_attention(q, c["xk"], c["xv"],
                                     jnp.full((x.shape[0],), enc_len - 1))
            x = x + L.out_proj(lp["cross"], out)
            h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.swiglu(lp["mlp"], h)
            new_c = dict(new_c, xk=c["xk"], xv=c["xv"])
            return x, new_c

        x, new_cache = jax.lax.scan(block, x, (params["decoder"], cache["decoder"]),
                                    unroll=cfg.scan_unroll)
        new_cache = {"decoder": new_cache}
    else:
        period = _superblock_period(cfg)

        def block(x, xs):
            blk, c = xs
            new_c = {}
            for j in range(period):
                lp, cj = blk[f"sub{j}"], c[f"sub{j}"]
                h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                if "attn" in lp:
                    out, new_c[f"sub{j}"] = _decode_attn_sublayer(
                        lp["attn"], cj, h, cfg, pos)
                    x = x + out
                else:
                    out, new_c[f"sub{j}"] = M.mamba_decode_step(
                        lp["mamba"], cj, h[:, 0], cfg)
                    x = x + out[:, None, :]
                if "ln2" in lp:
                    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
                    if "moe" in lp:
                        out, _ = MOE.moe_block(lp["moe"], h, cfg)
                        x = x + out
                    else:
                        x = x + L.swiglu(lp["mlp"], h)
            return x, new_c

        x, new_cache = jax.lax.scan(block, x, (params["blocks"], cache["blocks"]),
                                    unroll=cfg.scan_unroll)
        new_cache = {"blocks": new_cache}

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, x)[:, 0, :]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params, cfg, batch: dict, max_seq: int | None = None):
    """Run the full-context forward and build the decode cache.

    Implementation note: the backbone forward is reused (so prefill == sliced
    training forward, tested); caches are produced by re-running the qkv
    projections per layer inside the same scan.  For mamba sublayers the
    chunked scan's final state is the cache.
    """
    if cfg.family == "audio":
        return _prefill_encdec(params, cfg, batch, max_seq)
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    x = L.embed(params, tokens).astype(cfg.dtype)
    extra = batch.get("extra_embeds")
    if extra is not None:
        x = jnp.concatenate([extra.astype(cfg.dtype), x], axis=1)
        S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    period = _superblock_period(cfg)

    def block(carry, blk):
        x, aux = carry
        caches = {}
        for j in range(period):
            lp = blk[f"sub{j}"]
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            if "attn" in lp:
                q, k, v = L.qkv_proj(lp["attn"], h)
                q = L.rope(q, positions, cfg.rope_theta)
                k = L.rope(k, positions, cfg.rope_theta)
                if cfg.q_head_pad:
                    g = q.shape[2] // k.shape[2]
                    k = jnp.repeat(k, g, axis=2)
                    v = jnp.repeat(v, g, axis=2)
                out = L.chunked_attention(q, k, v, causal=True,
                                          block_q=cfg.block_q,
                                          block_kv=cfg.block_kv)
                x = x + L.out_proj(lp["attn"], out)
                pad = max_seq - S
                caches[f"sub{j}"] = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
            else:
                out, st = _mamba_block_with_state(lp["mamba"], h, cfg)
                x = x + out
                caches[f"sub{j}"] = st
            if "ln2" in lp:
                h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
                if "moe" in lp:
                    out, metrics = MOE.moe_block(lp["moe"], h, cfg)
                    x = x + out
                else:
                    x = x + L.swiglu(lp["mlp"], h)
        return (x, aux), caches

    (x, _), cache = jax.lax.scan(_remat(block, cfg), (x, {}), params["blocks"],
                                 unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, x[:, -1:, :])[:, 0, :]
    return logits, {"blocks": cache}


def _mamba_block_with_state(p, x, cfg):
    """mamba_block variant that also returns the decode state."""
    s = cfg.ssm
    Bsz, Sq, D = x.shape
    H = s.n_heads(cfg.d_model)
    Pdim = s.head_dim

    z = jnp.einsum("bld,de->ble", x, p["wz"])
    xin0 = jnp.einsum("bld,de->ble", x, p["wx"])
    Bm0 = jnp.einsum("bld,de->ble", x, p["wB"])
    Cm0 = jnp.einsum("bld,de->ble", x, p["wC"])
    dt = jnp.einsum("bld,de->ble", x, p["wdt"]).astype(jnp.float32)

    xin = jax.nn.silu(M._causal_conv(xin0, p["conv_x"]))
    Bm = jax.nn.silu(M._causal_conv(Bm0, p["conv_B"])).astype(jnp.float32)
    Cm = jax.nn.silu(M._causal_conv(Cm0, p["conv_C"])).astype(jnp.float32)

    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(Bsz, Sq, H, Pdim).astype(jnp.float32)
    xdt = xh * dt[..., None]
    y, S_final = M.ssd_chunked(xdt, dt * A, Bm, Cm, s.chunk,
                               unroll=cfg.ssd_unroll)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bsz, Sq, -1).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out"])
    W = s.d_conv
    state = {"ssm": S_final,
             "conv_x": xin0[:, -(W - 1):, :],
             "conv_B": Bm0[:, -(W - 1):, :],
             "conv_C": Cm0[:, -(W - 1):, :]}
    return out, state


def _prefill_encdec(params, cfg, batch, max_seq):
    src = batch["src_embeds"].astype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_seq = max_seq or S
    enc = _encoder_stack(params, cfg,
                         src, jnp.arange(src.shape[1])[None, :])
    x = L.embed(params, tokens).astype(cfg.dtype)
    positions = jnp.arange(S)[None, :]

    def block(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_proj(lp["attn"], h)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        out = L.chunked_attention(q, k, v, causal=True, block_q=cfg.block_q,
                                  block_kv=cfg.block_kv)
        x = x + L.out_proj(lp["attn"], out)
        h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        qx, xk, xv = L.qkv_proj(lp["cross"], h, enc)
        out = L.chunked_attention(qx, xk, xv, causal=False,
                                  block_q=cfg.block_q, block_kv=cfg.block_kv)
        x = x + L.out_proj(lp["cross"], out)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h)
        pad = max_seq - S
        cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "xk": xk, "xv": xv}
        return x, cache

    x, cache = jax.lax.scan(_remat(block, cfg), x, params["decoder"],
                            unroll=cfg.scan_unroll)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params, x[:, -1:, :])[:, 0, :]
    return logits, {"decoder": cache}

"""Transformer building blocks: norms, RoPE, chunked flash-style attention
(GQA), SwiGLU MLP, embeddings.  Pure functions over ParamSpec-described trees.

Attention is double-chunked (scan over query blocks, online-softmax scan over
key/value blocks) so the 32k/512k-context cells lower with O(block_q*block_kv)
score buffers instead of O(S^2) -- this is what makes the prefill_32k dry-run
memory-sane and is the standard TPU flash-attention formulation (the Pallas
TPU kernel would tile identically; on this CPU container the pure-JAX version
is the one the dry-run lowers).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import compat

from .module import ParamSpec

NEG_INF = -2.0 ** 30  # finite mask value: keeps fully-masked rows NaN-free


# ---------------------------------------------------------------- norms ----

def rmsnorm_spec(d: int, dtype) -> ParamSpec:
    return ParamSpec((d,), ("embed",), dtype, init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-rotation convention.  x (..., S, H, Dh),
    positions (..., S) int32 absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----

def attention_specs(cfg, *, cross: bool = False) -> dict:
    d, h, hkv, dh = (cfg.d_model, cfg.resolved_q_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    pd = cfg.param_dtype
    specs = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim"), pd),
        "wk": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim"), pd),
        "wv": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim"), pd),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed"), pd),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, dh), ("heads", "head_dim"), pd, init="zeros")
        specs["bk"] = ParamSpec((hkv, dh), ("kv_heads", "head_dim"), pd, init="zeros")
        specs["bv"] = ParamSpec((hkv, dh), ("kv_heads", "head_dim"), pd, init="zeros")
    return specs


def qkv_proj(p: dict, x: jax.Array, x_kv: jax.Array | None = None):
    """x (B, S, D) -> q (B, S, H, Dh), k/v (B, Skv, Hkv, Dh)."""
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def out_proj(p: dict, attn_out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])


def _gqa_scores(qb, kb, scale):
    # qb (B, bq, Hkv, G, Dh), kb (B, bkv, Hkv, Dh) -> (B, Hkv, G, bq, bkv) f32
    return jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                      preferred_element_type=jnp.float32) * scale


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_offset: int | jax.Array = 0, causal: bool = True,
                      block_q: int = 512, block_kv: int = 1024) -> jax.Array:
    """Online-softmax attention.  q (B, Sq, H, Dh); k, v (B, Skv, Hkv, Dh).
    Query position i attends to key positions <= q_offset + i when causal.
    Returns (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    Skv_real, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv_real)
    # Pad ragged sequence lengths up to block multiples; padded keys are
    # masked below, padded query rows are sliced away at the end.
    q_pad = (-Sq) % bq
    kv_pad = (-Skv_real) % bkv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    Sq_p, Skv = Sq + q_pad, Skv_real + kv_pad
    nq, nkv = Sq_p // bq, Skv // bkv

    qr = q.reshape(B, nq, bq, Hkv, G, Dh)
    del Sq_p
    kr = k.reshape(B, nkv, bkv, Hkv, Dh)
    vr = v.reshape(B, nkv, bkv, Hkv, Dh)

    # Flash-attention memory discipline for backward: checkpoint each q-block
    # so autodiff saves only the block output instead of every (bq x bkv)
    # probability tile of the online-softmax scan (which is O(S^2) per layer;
    # measured at 65 GB/device on qwen2 train_4k before this -- see
    # EXPERIMENTS.md section Perf, memory iteration 1).
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def one_q_block(iq, qb):
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, ikv):
            m, l, acc = carry
            kb = kr[:, ikv]
            vb = vr[:, ikv]
            s = _gqa_scores(qb, kb, scale)                     # (B,Hkv,G,bq,bkv)
            kpos = ikv * bkv + jnp.arange(bkv)
            mask = kpos[None, :] < Skv_real                    # exclude kv padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])  # (bq, bkv)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, Hkv, G, bq, Dh)

    outs = jax.lax.map(lambda args: one_q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # (nq, B, Hkv, G, bq, Dh) -> (B, Sq, H, Dh)
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, nq, Hkv, G, bq, Dh)
    outs = jnp.transpose(outs, (0, 1, 4, 2, 3, 5)).reshape(B, Sq + q_pad, H, Dh)
    return outs[:, :Sq].astype(q.dtype)


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """Single-step attention against a cache.  q (B, 1, H, Dh),
    cache (B, Smax, Hkv, Dh), pos scalar int32 = current position (attends to
    cache[:, :pos+1]).  Dense path: GSPMD decides the collective schedule
    (the all-gather this induces when the cache is sequence-sharded is the
    measured baseline that flash-decoding removes -- serve/engine.py)."""
    B, _, H, Dh = q.shape
    Smax, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qr = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, cache_k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.asarray(pos)
    pos_b = pos.reshape(-1, 1, 1, 1) if pos.ndim else pos  # (B,) or scalar
    mask = jnp.arange(Smax)[None, None, None, :] <= pos_b
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def decode_attention_seqsharded(q, cache_k, cache_v, pos, *, mesh, axis="model"):
    """Flash-decoding: cache sequence-sharded over ``axis``; each shard
    computes a partial softmax over its local keys and the partials are
    combined with ONE psum of (numerator, denominator, max) instead of
    all-gathering the cache/scores.  Beyond-paper optimization in the same
    spirit as the CA fused packet: replace per-step gathers of O(S) state with
    a single tiny reduction."""
    from jax.sharding import PartitionSpec as P
    B, _, H, Dh = q.shape
    Hkv = cache_k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    n_shards = mesh.shape[axis]
    S_local = cache_k.shape[1] // n_shards

    def local(qr, kl, vl):
        shard = jax.lax.axis_index(axis)
        kpos = shard * S_local + jnp.arange(S_local)
        s = jnp.einsum("bhgd,bkhd->bhgk", qr.reshape(B, Hkv, G, Dh), kl,
                       preferred_element_type=jnp.float32) * scale
        pos_a = jnp.asarray(pos)
        pos_b = pos_a.reshape(-1, 1, 1, 1) if pos_a.ndim else pos_a
        s = jnp.where(kpos[None, None, None, :] <= pos_b, s, NEG_INF)
        m = s.max(axis=-1)                                   # (B,Hkv,G) local max
        p = jnp.exp(s - m[..., None])
        num = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vl.dtype), vl,
                         preferred_element_type=jnp.float32)
        den = p.sum(axis=-1)
        # one fused packet: global max via two-pass-free rescale trick
        # (this layer's reduction is its own communication point, deliberately
        # outside the solver engine's _packet_reduce -- hence the waivers)
        gmax = jax.lax.pmax(m, axis)  # contract: allow-collective
        r = jnp.exp(m - gmax)
        packet = jnp.concatenate(
            [num * r[..., None], (den * r)[..., None]], axis=-1)
        packet = jax.lax.psum(packet, axis)  # contract: allow-collective  (B,Hkv,G,Dh+1)
        out = packet[..., :Dh] / jnp.maximum(packet[..., Dh:], 1e-30)
        return out.reshape(B, 1, H, Dh).astype(qr.dtype)

    fn = compat.shard_map(  # contract: allow-collective
        local, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None)),
        out_specs=P())
    return fn(q, cache_k, cache_v)


# ------------------------------------------------------------------ mlp ----

def mlp_specs(cfg) -> dict:
    d, f, pd = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "w1": ParamSpec((d, f), ("embed", "mlp"), pd),
        "w3": ParamSpec((d, f), ("embed", "mlp"), pd),
        "w2": ParamSpec((f, d), ("mlp", "embed"), pd),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    g = jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", h * g, p["w2"])


# ----------------------------------------------------------- embeddings ----

def embed_specs(cfg) -> dict:
    pd = cfg.param_dtype
    specs = {"embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                    ("vocab", "embed"), pd,
                                    scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), pd)
    return specs


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    table = p.get("lm_head")
    if table is None:
        return jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    return jnp.einsum("bsd,dv->bsv", x, table)

"""Model zoo substrate: functional layers + the three assembled bodies
(decoder / enc-dec) covering all 10 assigned architectures."""
from . import api, layers, mamba2, moe
from .module import (ParamSpec, abstract_params, init_params, param_bytes,
                     param_count, stack_specs)
from .sharding import BASE_RULES, ShardingRules, constrain, make_rules

__all__ = ["api", "layers", "mamba2", "moe", "ParamSpec", "abstract_params",
           "init_params", "param_bytes", "param_count", "stack_specs",
           "BASE_RULES", "ShardingRules", "constrain", "make_rules"]

"""Minimal functional module system: parameter trees described by ParamSpec.

Every model declares a nested dict of ParamSpec (shape + logical axes + init).
From that single description we derive:
  * materialized parameters (init_params) for real runs,
  * abstract ShapeDtypeStructs with NamedShardings (abstract_params) for the
    multi-pod dry-run -- no allocation ever happens for the full configs,
  * sharding specs for jit in_shardings (via repro.models.sharding).

Keeping shapes and shardings in one tree prevents init/spec drift by
construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis names, len == ndim
    dtype: Any = jnp.bfloat16
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float | None = None        # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dimension (for scan-over-layers parameter layout)."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype,
                            s.init, s.scale), tree)


def _init_one(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    # fan-in scaled normal: last-but-one axis is the contraction by convention
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def init_params(tree, key: jax.Array):
    """Materialize a ParamSpec tree into arrays (deterministic in key)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree, sharding_fn):
    """ShapeDtypeStruct tree with shardings; ``sharding_fn(spec) -> Sharding``."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sharding_fn(s)),
        tree)


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)

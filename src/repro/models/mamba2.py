"""Mamba-2 (SSD, state-space duality) block: chunked scan formulation.

TPU adaptation: the SSD chunk decomposition is exactly the blocked form that
feeds the MXU -- intra-chunk work is a masked (q x q) matmul, inter-chunk work
is a sequential state pass (lax.scan) over chunk boundaries, so the O(L) scan
touches only (B, H, P, N) states while all O(L * q) work is BLAS-3.  This is
the same tiling a Pallas SSD kernel would use; the reference recurrence oracle
(naive_ssd) validates it token-by-token in tests.

Decode is O(1): one state update per token, no cache growth -- which is why
the ssm/hybrid archs are the ones that run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rmsnorm
from .module import ParamSpec


def mamba_specs(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    gn = s.n_groups * s.d_state
    pd = cfg.param_dtype
    return {
        "wz": ParamSpec((d, di), ("embed", "inner"), pd),
        "wx": ParamSpec((d, di), ("embed", "inner"), pd),
        "wB": ParamSpec((d, gn), ("embed", "state"), pd),
        "wC": ParamSpec((d, gn), ("embed", "state"), pd),
        "wdt": ParamSpec((d, h), ("embed", "inner"), pd),
        "conv_x": ParamSpec((s.d_conv, di), ("conv", "inner"), pd, scale=0.5),
        "conv_B": ParamSpec((s.d_conv, gn), ("conv", "state"), pd, scale=0.5),
        "conv_C": ParamSpec((s.d_conv, gn), ("conv", "state"), pd, scale=0.5),
        "A_log": ParamSpec((h,), ("inner",), jnp.float32, init="zeros"),
        "D": ParamSpec((h,), ("inner",), jnp.float32, init="ones"),
        "dt_bias": ParamSpec((h,), ("inner",), jnp.float32, init="zeros"),
        "norm": ParamSpec((di,), ("inner",), pd, init="ones"),
        "out": ParamSpec((di, d), ("inner", "embed"), pd),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv; x (B, L, C), kernel (W, C)."""
    W = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
              for i in range(W))
    return out


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., q) -> (..., q, q) with ss[i, j] = sum_{k=j+1..i} a_k (i >= j)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xdt: jax.Array, dtA: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, S0: jax.Array | None = None, unroll: int = 1):
    """SSD scan.  xdt (B, L, H, P) = x * dt; dtA (B, L, H) = dt * A (negative);
    Bm, Cm (B, L, N) (single group broadcast over heads).
    Returns (y (B, L, H, P), final state (B, H, P, N))."""
    Bsz, L, H, Pdim = xdt.shape
    N = Bm.shape[-1]
    q = min(chunk, L)
    pad = (-L) % q
    if pad:
        # Zero padding is state-neutral: dtA=0 => decay exp(0)=1, xdt=0 =>
        # no input; padded outputs are sliced off below.
        widths = lambda t: [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2)
        xdt = jnp.pad(xdt, widths(xdt))
        dtA = jnp.pad(dtA, widths(dtA))
        Bm = jnp.pad(Bm, widths(Bm))
        Cm = jnp.pad(Cm, widths(Cm))
    L_p = L + pad
    nc = L_p // q

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, q, *t.shape[2:]), 1, 0)

    xs = (to_chunks(xdt), to_chunks(dtA), to_chunks(Bm), to_chunks(Cm))
    S_init = (jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
              if S0 is None else S0.astype(jnp.float32))

    def chunk_step(S, inp):
        xc, ac, bc, cc = inp            # (B,q,H,P), (B,q,H), (B,q,N), (B,q,N)
        cum = jnp.cumsum(ac, axis=1)                       # (B,q,H)
        Lmat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, 1)))   # (B,H,q,q)
        y_diag = jnp.einsum("bqn,bkn,bhqk,bkhp->bqhp", cc, bc, Lmat, xc,
                            preferred_element_type=jnp.float32)
        decay_out = jnp.exp(cum)                           # (B,q,H)
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", cc, S, decay_out,
                           preferred_element_type=jnp.float32)
        decay_states = jnp.exp(cum[:, -1:, :] - cum)       # (B,q,H)
        S_new = S * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bkn,bkh,bkhp->bhpn", bc, decay_states, xc,
            preferred_element_type=jnp.float32)
        return S_new, y_diag + y_off

    S, ys = jax.lax.scan(chunk_step, S_init, xs, unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L_p, H, Pdim)
    return y[:, :L], S


def naive_ssd(xdt, dtA, Bm, Cm, S0=None):
    """Token-by-token recurrence oracle: S_t = S_{t-1} exp(dtA_t) + B_t (x dt)_t."""
    Bsz, L, H, Pdim = xdt.shape
    N = Bm.shape[-1]
    S = jnp.zeros((Bsz, H, Pdim, N), jnp.float32) if S0 is None else S0

    def step(S, inp):
        xt, at, bt, ct = inp           # (B,H,P), (B,H), (B,N), (B,N)
        S = S * jnp.exp(at)[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", S, ct)
        return S, y

    xs = (jnp.moveaxis(xdt, 1, 0), jnp.moveaxis(dtA, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    S, ys = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(ys, 0, 1), S


def mamba_block(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Full Mamba-2 block forward; x (B, L, D) -> (B, L, D)."""
    s = cfg.ssm
    Bsz, L, D = x.shape
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    Pdim = s.head_dim

    z = jnp.einsum("bld,de->ble", x, p["wz"])
    xin = jnp.einsum("bld,de->ble", x, p["wx"])
    Bm = jnp.einsum("bld,de->ble", x, p["wB"])
    Cm = jnp.einsum("bld,de->ble", x, p["wC"])
    dt = jnp.einsum("bld,de->ble", x, p["wdt"]).astype(jnp.float32)

    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"])).astype(jnp.float32)
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"])).astype(jnp.float32)

    dt = jax.nn.softplus(dt + p["dt_bias"])                 # (B,L,H)
    A = -jnp.exp(p["A_log"])                                # (H,) negative
    xh = xin.reshape(Bsz, L, H, Pdim).astype(jnp.float32)
    xdt = xh * dt[..., None]
    dtA = dt * A

    y, _ = ssd_chunked(xdt, dtA, Bm, Cm, s.chunk, unroll=cfg.ssd_unroll)
    y = y + xh * p["D"][None, None, :, None]                # skip connection
    y = y.reshape(Bsz, L, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, p["out"])


# ------------------------------------------------------------- decode ----

def mamba_state_init(cfg, batch: int) -> dict:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    W = s.d_conv
    return {
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((batch, W - 1, di), cfg.dtype),
        "conv_B": jnp.zeros((batch, W - 1, gn), cfg.dtype),
        "conv_C": jnp.zeros((batch, W - 1, gn), cfg.dtype),
    }


def _conv_step(buf: jax.Array, xt: jax.Array, kernel: jax.Array):
    """One causal-conv step; buf (B, W-1, C) history, xt (B, C)."""
    window = jnp.concatenate([buf, xt[:, None, :]], axis=1)   # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, kernel)
    return window[:, 1:, :], out


def mamba_decode_step(p: dict, state: dict, xt: jax.Array, cfg):
    """One-token state update; xt (B, D) -> ((B, D), new state).  O(1) in L."""
    s = cfg.ssm
    Bsz, D = xt.shape
    H = s.n_heads(cfg.d_model)
    Pdim = s.head_dim

    z = xt @ p["wz"]
    xin = xt @ p["wx"]
    Bm = xt @ p["wB"]
    Cm = xt @ p["wC"]
    dt = (xt @ p["wdt"]).astype(jnp.float32)

    conv_x, xin = _conv_step(state["conv_x"], xin, p["conv_x"])
    conv_B, Bm = _conv_step(state["conv_B"], Bm, p["conv_B"])
    conv_C, Cm = _conv_step(state["conv_C"], Cm, p["conv_C"])
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt + p["dt_bias"])                  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(Bsz, H, Pdim).astype(jnp.float32)
    S = state["ssm"] * jnp.exp(dt * A)[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xh * dt[..., None], Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, Cm.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, -1).astype(xt.dtype)
    y = rmsnorm((y * jax.nn.silu(z))[:, None, :], p["norm"], cfg.norm_eps)[:, 0]
    out = y @ p["out"]
    new_state = {"ssm": S, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
    return out, new_state

"""Logical-axis -> mesh-axis sharding rules with divisibility guards.

MaxText-style rule tables.  A logical axis maps to a tuple of mesh axes; the
guard drops any mapping whose mesh-axis product does not divide the dimension
(e.g. llama3.2's 24 query heads cannot shard over model=16 and fall back to
replication -- recorded so the roofline report can call it out) and any mesh
axis that is absent from the current mesh (so single-pod and multi-pod meshes
share one rule table: 'pod' simply vanishes on the 16x16 mesh).

Vocab dims are padded (configs.pad_vocab) rather than guarded -- the standard
Megatron treatment -- because replicating a 131k x d_model embedding is never
acceptable.
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .module import ParamSpec

# One shared rule table.  "fsdp" entries are merged in when the config asks
# for parameter sharding over the data axis (ZeRO-3 style for the >100B archs).
BASE_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "inner": ("model",),        # mamba d_inner / heads
    "cache_seq": (),            # overridden to ("model",) for seq-sharded decode
    "seq": (),
    "embed": (),
    "layers": (),
    "head_dim": (),
    "state": (),
    "conv": (),
    "capacity": (),
    "data_points": ("pod", "data", "model"),  # solver 1D-block-column layout
    "features": ("pod", "data", "model"),     # solver 1D-block-row layout
}

FSDP_RULES = {
    "embed": ("data",),         # shard the non-TP dim of weight matrices
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]]
    dropped: list  # (axes, dim, logical, reason) audit trail

    def spec_for(self, shape: tuple[int, ...], axes: tuple[str | None, ...]) -> P:
        used: set[str] = set()
        parts = []
        for dim, logical in zip(shape, axes):
            choice = None
            if logical is not None:
                candidates = self.rules.get(logical, ())
                # keep only axes present in the mesh and not yet used
                cand = tuple(a for a in candidates
                             if a in self.mesh.shape and a not in used)
                # try the full tuple, then prefixes, then singletons
                options = []
                if cand:
                    options.append(cand)
                    options.extend((a,) for a in cand if len(cand) > 1)
                for opt in options:
                    size = math.prod(self.mesh.shape[a] for a in opt)
                    if dim % size == 0:
                        choice = opt
                        used.update(opt)
                        break
                if choice is None and cand:
                    self.dropped.append((logical, dim, cand, "indivisible"))
            parts.append(choice if choice is None or len(choice) > 1
                         else choice[0])
        # PartitionSpec wants None for replicated dims
        return P(*[p if p else None for p in parts])

    def sharding_for(self, spec: ParamSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(spec.shape, spec.axes))

    def named(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


def make_rules(mesh: Mesh, *, fsdp: bool = False,
               overrides: dict[str, tuple[str, ...]] | None = None) -> ShardingRules:
    rules = dict(BASE_RULES)
    if fsdp:
        rules.update(FSDP_RULES)
    if overrides:
        rules.update(overrides)
    return ShardingRules(mesh, rules, dropped=[])


def constrain(x, rules: ShardingRules, axes: tuple[str | None, ...]):
    """with_sharding_constraint by logical axes (activation annotations)."""
    return jax.lax.with_sharding_constraint(x, rules.named(x.shape, axes))

"""Mixture-of-Experts block: top-k routing with sort-based capacity dispatch.

Sort-based dispatch (argsort by expert id + positional ranking) avoids the
O(tokens * experts * capacity) one-hot dispatch tensors that make einsum-MoE
unloweable at 32k contexts; the per-expert buffers are (E, C, D) with
C = ceil(tokens * k / E * capacity_factor).  Experts are sharded over the
'model' mesh axis (EP=16 for the 16-expert archs) and the scatter/gather pair
lowers to all-to-alls under GSPMD -- the collective-bound behaviour the
roofline section measures for dbrx/phi3.5/jamba.

Overflowed tokens (beyond capacity) are dropped (their combine weight is 0 and
the residual connection carries them) -- the Switch/GShard convention; drop
fraction is returned as a metric and tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import ParamSpec


def moe_specs(cfg) -> dict:
    d, f, pd = cfg.d_model, cfg.d_ff, cfg.param_dtype
    e = cfg.moe.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", None), jnp.float32),
        "w1": ParamSpec((e, d, f), ("expert", "embed", "mlp"), pd),
        "w3": ParamSpec((e, d, f), ("expert", "embed", "mlp"), pd),
        "w2": ParamSpec((e, f, d), ("expert", "mlp", "embed"), pd),
    }


def _capacity(tokens: int, k: int, e: int, factor: float) -> int:
    cap = int(tokens * k / e * factor)
    return max(8, -(-cap // 8) * 8)  # pad to 8 for clean layouts


def moe_block(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """x (B, S, D) -> (B, S, D), metrics.  Top-k routing, capacity C.

    With ``cfg.moe.groups > 1`` the dispatch (sort, ranking, capacity) runs
    independently per token group (GShard convention).  The group dim inherits
    the batch sharding, so sorting becomes a *batched local* sort -- no
    cross-shard collective -- and capacity is enforced per group.  Measured in
    EXPERIMENTS.md section Perf C2.
    """
    mcfg = cfg.moe
    B, S, D = x.shape
    T_all = B * S
    G = mcfg.groups
    if G > 1:
        if T_all % G:
            raise ValueError(f"tokens {T_all} not divisible by groups {G}")
        xg = x.reshape(G, T_all // G, D)
        outs, metrics = jax.vmap(
            lambda xs: _moe_dispatch(p, xs, cfg))(xg)
        out = outs.reshape(B, S, D)
        return out, {k: v.mean() for k, v in metrics.items()}
    out, metrics = _moe_dispatch(p, x.reshape(T_all, D), cfg)
    return out.reshape(B, S, D), metrics


def _moe_dispatch(p: dict, xf: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """Sort-based top-k dispatch over a flat token group xf (T, D)."""
    mcfg = cfg.moe
    T, D = xf.shape
    E, K = mcfg.num_experts, mcfg.top_k
    C = _capacity(T, K, E, mcfg.capacity_factor)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)  # renormalize top-k

    # ---- sort-based dispatch ------------------------------------------
    expert_flat = sel.reshape(T * K)
    token_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    gate_flat = gate.reshape(T * K)
    order = jnp.argsort(expert_flat)                        # stable
    e_sorted = expert_flat[order]
    t_sorted = token_flat[order]
    g_sorted = gate_flat[order]
    counts = jnp.bincount(expert_flat, length=E)            # tokens per expert
    starts = jnp.cumsum(counts) - counts                    # exclusive prefix
    pos_in_expert = jnp.arange(T * K) - starts[e_sorted]
    keep = pos_in_expert < C
    dest = jnp.where(keep, e_sorted * C + pos_in_expert, E * C)  # E*C = drop slot

    # gather tokens into (E*C, D) buffers (dropped -> ignored via mode="drop")
    buf = jnp.zeros((E * C, D), xf.dtype)
    buf = buf.at[dest].set(xf[t_sorted], mode="drop")
    buf = buf.reshape(E, C, D)

    # ---- expert computation (EP over 'model' via w sharding) -----------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"]))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", h * g, p["w2"]).reshape(E * C, D)

    # ---- combine --------------------------------------------------------
    slot_out = jnp.where(keep[:, None],
                         jnp.take(out_buf, jnp.minimum(dest, E * C - 1), axis=0),
                         0.0)
    out = jnp.zeros((T, D), jnp.float32).at[t_sorted].add(
        slot_out.astype(jnp.float32) * g_sorted[:, None])

    # ---- aux losses / metrics ------------------------------------------
    me = probs.mean(axis=0)                                  # mean router prob
    ce = jnp.bincount(sel.reshape(-1), length=E).astype(jnp.float32) / (T * K)
    aux = E * jnp.sum(me * ce) * mcfg.aux_loss_weight        # Switch LB loss
    drop_frac = 1.0 - keep.sum().astype(jnp.float32) / (T * K)
    return out.astype(xf.dtype), {
        "moe_aux_loss": aux, "moe_drop_frac": drop_frac}

"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests and benches keep their 1-device world while
dryrun.py (which sets XLA_FLAGS before any import) gets 512.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ('data', 'model'); multi_pod prepends a
    2-pod DCN axis ('pod', 'data', 'model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)

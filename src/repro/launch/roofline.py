"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md section Roofline).

Hardware model (TPU v5e, from the assignment):
    peak = 197 TFLOP/s bf16 per chip
    HBM  = 819 GB/s per chip
    ICI  = ~50 GB/s per link

Terms per (arch x shape x mesh) cell -- all per-step seconds:
    compute    = HLO_flops / (chips * peak)          [probe-extrapolated]
    memory     = HLO_bytes / (chips * HBM)           [probe-extrapolated]
    collective = collective_bytes / (chips * ICI)    [operand-sum convention]
                 (ring-model per-device link bytes reported alongside)

HLO flops/bytes come from ``compiled.cost_analysis()`` on the cost-probe
compiles (shallow fully-unrolled at full width, linearly extrapolated --
dryrun.py), because XLA counts while-loop bodies once; cost_analysis is
per-device on this JAX version (verified), so global = per_device * chips.

MODEL_FLOPS = 6*N*D for training (2*N*D for inference cells), N = active
params, D = tokens per step; the MODEL/HLO ratio flags remat + replication
waste (e.g. qwen2's unshardable 14 heads replicate attention 16x).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config, n_active_params, n_params

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link


def model_flops(cfg, shape) -> float:
    n_act = n_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_act * tokens


def load_cells(art_dir: str) -> dict:
    cells = {}
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        key = (rec["arch"], rec["shape"], rec["mesh"])
        slot = "probe" if rec.get("probe") else "base"
        cells.setdefault(key, {})[slot] = rec
    return cells


def analyze_cell(arch: str, shape_name: str, mesh: str, base: dict,
                 probe: dict | None) -> dict:
    cfg = get_config(arch.split("+")[0])   # "+tag" = optimized variant rows
    shape = SHAPES[shape_name]
    chips = base.get("chips", 256)
    out = {"arch": arch, "shape": shape_name, "mesh": mesh,
           "status": base["status"]}
    if base["status"] != "ok":
        out["reason"] = base.get("reason", base.get("error", ""))
        return out
    if probe and probe.get("status") == "ok":
        ex = probe["extrapolated_per_device"]
        flops_dev = ex["flops"]
        bytes_dev = ex["bytes_accessed"]
        coll_operand_dev = ex["coll_operand_bytes"]
        coll_link_dev = ex["coll_link_bytes"]
        coll_count = ex["coll_count"]
        out["cost_source"] = "probe-extrapolated"
    else:  # fall back to the rolled compile (documented undercount)
        flops_dev = base["cost_analysis"]["flops_per_device"]
        bytes_dev = base["cost_analysis"]["bytes_accessed_per_device"]
        coll_operand_dev = base["collectives"]["operand_bytes"]
        coll_link_dev = base["collectives"]["link_bytes"]
        coll_count = base["collectives"]["count"]
        out["cost_source"] = "rolled (loop bodies counted once)"

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_operand_dev / LINK_BW          # prompt convention
    coll_ring_s = coll_link_dev / LINK_BW        # ring model (physical)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful_s = mf / (chips * PEAK_FLOPS)
    bound_s = max(terms.values())
    mem = base["memory_analysis"]
    hbm_bytes = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
    out.update({
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "collective_ring_s": coll_ring_s,
        "coll_count": coll_count,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * chips,
        "model_over_hlo": mf / max(flops_dev * chips, 1.0),
        "roofline_fraction": useful_s / max(bound_s, 1e-30),
        "hbm_gb_per_device": hbm_bytes / 1e9,
        "fits_16gb": hbm_bytes < 16e9,
        "n_params": n_params(cfg),
        "n_active": n_active_params(cfg),
    })
    out["advice"] = _advice(out)
    return out


def _advice(c: dict) -> str:
    d = c["dominant"]
    if d == "collective":
        return ("reduce wire bytes: bf16/int8 collectives, fused packets, "
                "or move the bottleneck axis to sequence/expert sharding")
    if d == "memory":
        return ("cut HBM traffic: tighter remat policy, fused loss (no "
                "materialized logits), larger arithmetic intensity per pass")
    if c["model_over_hlo"] < 0.25:
        return ("compute-bound but mostly waste: replicated attention or "
                "remat overhead dominates -- reshard (context parallelism / "
                "head padding) before buying flops")
    return "compute-bound and mostly useful: increase per-chip utilization (MXU tiling)"


def table(cells: dict, mesh: str = "single") -> str:
    rows = []
    header = ("| arch | shape | compute s | memory s | collective s | dominant "
              "| 6ND/HLO | roofline frac | HBM GB/dev | fits |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for (arch, shape, m), slots in sorted(cells.items()):
        if m != mesh or "base" not in slots:
            continue
        c = analyze_cell(arch, shape, m, slots["base"], slots.get("probe"))
        if c["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | -- | -- | -- | skipped: "
                        f"{c['reason'][:40]} | -- | -- | -- | -- |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {arch} | {shape} | FAILED | | | | | | | |")
            continue
        rows.append(
            f"| {arch} | {shape} | {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | {c['dominant']} "
            f"| {c['model_over_hlo']:.3f} | {c['roofline_fraction']:.3f} "
            f"| {c['hbm_gb_per_device']:.1f} | {'y' if c['fits_16gb'] else 'N'} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    cells = load_cells(args.artifacts)
    print(table(cells, args.mesh))
    results = []
    for (arch, shape, m), slots in sorted(cells.items()):
        if "base" in slots:
            results.append(analyze_cell(arch, shape, m, slots["base"],
                                        slots.get("probe")))
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\n[roofline] wrote {args.json_out}")


if __name__ == "__main__":
    main()

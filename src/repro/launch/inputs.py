"""Abstract input construction for every (architecture x shape) dry-run cell.

ShapeDtypeStruct stand-ins only -- weak-type-correct, shardable, never
allocated.  The same functions drive the real launchers (which materialize
arrays with identical shardings), so the dry-run lowers exactly the production
program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.models.module import ParamSpec
from repro.models.sharding import make_rules
from repro.train.trainer import abstract_train_state


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Abstract training/prefill batch."""
    rules = make_rules(mesh, fsdp=cfg.fsdp)
    B, S = shape.global_batch, shape.seq_len
    bspec = rules.spec_for((B, S), ("batch", "seq"))
    out = {}
    if cfg.family == "audio":
        S_enc = max(S // 4, 128)
        espec = rules.spec_for((B, S_enc, cfg.d_model), ("batch", "seq", "embed"))
        out["src_embeds"] = _sds((B, S_enc, cfg.d_model), cfg.dtype, mesh, espec)
        out["tokens"] = _sds((B, S), jnp.int32, mesh, bspec)
    elif cfg.family == "vlm":
        ft = cfg.frontend_tokens
        st = S - ft
        espec = rules.spec_for((B, ft, cfg.d_model), ("batch", "seq", "embed"))
        out["extra_embeds"] = _sds((B, ft, cfg.d_model), cfg.dtype, mesh, espec)
        tspec = rules.spec_for((B, st), ("batch", "seq"))
        out["tokens"] = _sds((B, st), jnp.int32, mesh, tspec)
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, bspec)
    if shape.kind == "train":
        lab_shape = out["tokens"].shape
        lspec = rules.spec_for(lab_shape, ("batch", "seq"))
        out["labels"] = _sds(lab_shape, jnp.int32, mesh, lspec)
        out["mask"] = _sds(lab_shape, jnp.float32, mesh, lspec)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                 seq_shard: bool = True) -> tuple:
    """(params, cache, token, pos) abstract operands for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    overrides = {"cache_seq": ("model",)} if seq_shard else {}
    rules = make_rules(mesh, fsdp=cfg.fsdp, overrides=overrides)
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=rules.sharding_for(s)),
        api.param_specs(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))
    cache = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=rules.sharding_for(s)),
        api.init_cache_specs(cfg, B, S),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    tspec = rules.spec_for((B,), ("batch",))
    tok = _sds((B,), jnp.int32, mesh, tspec)
    pos = _sds((B,), jnp.int32, mesh, tspec)
    return params, cache, tok, pos


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> tuple:
    """(params, batch) abstract operands for the prefill step."""
    rules = make_rules(mesh, fsdp=cfg.fsdp)
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=rules.sharding_for(s)),
        api.param_specs(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))
    return params, batch_specs(cfg, shape, mesh)


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> tuple:
    """(state, batch) abstract operands for train_step."""
    state = abstract_train_state(cfg, mesh)
    return state, batch_specs(cfg, shape, mesh)

"""Training launcher: ``python -m repro.launch.train --arch qwen2_0_5b
--preset cpu-small --steps 200``.

Presets size the run to the environment; the sharded path uses the same
train_step the dry-run compiles.  On a real pod this process runs once per
host with jax.distributed.initialize() (single-process here).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, get_reduced
from repro.train import Trainer, TrainRunConfig
from repro.train.elastic import plan_mesh


PRESETS = {
    # ~10M params, runs on this CPU container in minutes
    "cpu-small": dict(reduced=True, steps=200, global_batch=8, seq_len=256,
                      lr=1e-3, d_model=256, n_layers=4),
    # ~100M params: the end-to-end deliverable scale (hours on CPU, minutes on
    # a v5e slice)
    "100m": dict(reduced=True, steps=300, global_batch=32, seq_len=1024,
                 lr=6e-4, d_model=768, n_layers=12),
    # full published geometry (pods only)
    "full": dict(reduced=False, steps=1000, global_batch=256, seq_len=4096,
                 lr=3e-4),
}


def build_model_cfg(arch: str, preset: dict):
    if not preset.get("reduced"):
        return get_config(arch)
    cfg = get_reduced(arch)
    kw = {}
    if "d_model" in preset:
        d = preset["d_model"]
        kw.update(d_model=d, d_ff=4 * d)
        if cfg.n_heads:
            kw.update(n_heads=max(d // 64, 1) , head_dim=64,
                      n_kv_heads=max(min(cfg.n_kv_heads, d // 64), 1))
    if "n_layers" in preset:
        from repro.models.api import _superblock_period
        period = _superblock_period(cfg)
        layers = max(preset["n_layers"] // period, 1) * period
        kw.update(n_layers=layers)
        if cfg.family == "audio":
            kw.update(enc_layers=layers)
    cfg = dataclasses.replace(cfg, **kw)
    return dataclasses.replace(cfg, vocab=get_config(arch).vocab // 4)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--preset", default="cpu-small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    help="'none' (single device), 'auto' (all local devices)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for init + data stream (fixed default "
                         "=> reproducible loss trajectory)")
    args = ap.parse_args()

    preset = dict(PRESETS[args.preset])
    if args.steps:
        preset["steps"] = args.steps
    model_cfg = build_model_cfg(args.arch, preset)
    run_cfg = TrainRunConfig(
        steps=preset["steps"], global_batch=preset["global_batch"],
        seq_len=preset["seq_len"], lr=preset["lr"], ckpt_dir=args.ckpt_dir,
        seed=args.seed)
    mesh = None
    if args.mesh == "auto" and len(jax.devices()) > 1:
        mesh = plan_mesh(len(jax.devices()))
    from repro.configs import n_params as npar
    print(f"[train] arch={model_cfg.name} params~{npar(model_cfg)/1e6:.1f}M "
          f"steps={run_cfg.steps} batch={run_cfg.global_batch} "
          f"seq={run_cfg.seq_len}")
    trainer = Trainer(model_cfg, run_cfg, mesh=mesh)
    hist = trainer.run()
    if hist:
        print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

"""Serving launcher: batched generation demo over the slot engine.

``python -m repro.launch.serve --arch llama3_2_3b --requests 6 --max-new 16``
uses the reduced config so it runs on CPU; on hardware the full config plus a
mesh (decode_specs shardings) serve the production decode program the dry-run
compiles for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import api, init_params
from repro.serve import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for params + prompts (fixed default "
                         "=> reproducible outputs)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(api.param_specs(cfg), jax.random.key(args.seed))
    eng = Engine(cfg, params, ServeConfig(
        max_seq=512, slots=args.slots, temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    chunk = cfg.ssm.chunk if cfg.ssm else 8
    prompts = [list(rng.integers(1, cfg.vocab, size=chunk))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = eng.generate(prompts, args.max_new)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    print(f"[serve] {args.requests} requests x {args.max_new} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s aggregate, {args.slots} slots)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12]}{'...' if len(o) > 12 else ''}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place the 512-device world
# exists; tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record the artifacts the roofline analysis reads.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch ID|all]
        [--shape NAME|all] [--mesh single|multi|both] [--out DIR]
        [--seq-shard-decode true|false]

Per cell this emits artifacts/dryrun/<arch>__<shape>__<mesh>.json with:
  memory_analysis   (per-device argument/output/temp bytes -- proves fit)
  cost_analysis     (per-device HLO flops / bytes accessed)
  collectives       (count + operand/link bytes by kind, parsed from HLO)
  timings           (lower / compile wall seconds)
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.hlo_analysis import collective_summary
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.optim import AdamWConfig
from repro.train.trainer import make_train_step


def _cell_program(cfg, shape, mesh, seq_shard_decode=True):
    """Returns (jitted_fn, abstract_args) for the cell's step program."""
    if shape.kind == "train":
        state, batch = I.train_specs(cfg, shape, mesh)
        step = make_train_step(cfg, AdamWConfig(lr=1e-4), microbatches=1)
        return jax.jit(step, donate_argnums=0), (state, batch)
    if shape.kind == "prefill":
        params, batch = I.prefill_specs(cfg, shape, mesh)

        def prefill_fn(p, b):
            return api.prefill(p, cfg, b, max_seq=shape.seq_len)

        return jax.jit(prefill_fn), (params, batch)
    # decode
    params, cache, tok, pos = I.decode_specs(cfg, shape, mesh,
                                             seq_shard=seq_shard_decode)

    def serve_step(p, c, t, q):
        return api.decode_step(p, cfg, c, t, q)

    return jax.jit(serve_step, donate_argnums=1), (params, cache, tok, pos)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             seq_shard_decode: bool = True, verbose: bool = True,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape.applicable(cfg)
    rec = {"arch": cfg.name + tag, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _write(rec, out_dir)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    try:
        fn, args = _cell_program(cfg, shape, mesh, seq_shard_decode)
        t0 = time.time()
        with mesh:
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        colls = collective_summary(compiled.as_text(), total_devices=n_chips)
        rec.update({
            "status": "ok",
            "chips": n_chips,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory_analysis": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "code_bytes": ma.generated_code_size_in_bytes,
            },
            "cost_analysis": {
                "flops_per_device": ca.get("flops", 0.0),
                "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
            },
            "collectives": {
                "count": colls.count,
                "operand_bytes": colls.operand_bytes,
                "link_bytes": colls.link_bytes,
                "by_kind": {k: {"count": v[0], "operand_bytes": v[1],
                                "link_bytes": v[2]}
                            for k, v in colls.by_kind.items()},
            },
        })
        if verbose:
            print(compiled.memory_analysis())
            print({k: v for k, v in ca.items()
                   if k in ("flops", "bytes accessed")})
    except Exception as e:  # a failing cell is a bug; record and re-raise later
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(rec, out_dir)
    return rec


def _parse_set(spec: str | None) -> dict | None:
    """--set k=v[,k=v]: ints, with moe_* keys routed into the MoE config."""
    if not spec:
        return None
    out = {}
    for kv in spec.split(","):
        k, v = kv.split("=")
        out[k] = int(v)
    moe_keys = {k[4:]: v for k, v in out.items() if k.startswith("moe_")}
    out = {k: v for k, v in out.items() if not k.startswith("moe_")}
    if moe_keys:
        out["__moe__"] = moe_keys
    return out


def _apply_overrides(cfg, overrides: dict):
    overrides = dict(overrides)
    moe_keys = overrides.pop("__moe__", None)
    if moe_keys and cfg.moe:
        overrides["moe"] = dataclasses.replace(cfg.moe, **moe_keys)
    return dataclasses.replace(cfg, **overrides)


def _probe_cfg(cfg, depth: int, period: int, shape):
    """Full-width, shallow-depth, fully-unrolled config for exact HLO cost
    counting.  Attention runs single-block (flops-identical: chunking splits
    the same matmuls); the SSD chunk scan and the layer scan are unrolled so
    XLA's cost analysis (which counts while-loop bodies once) sees every op."""
    kw = dict(n_layers=depth, scan_unroll=max(depth // period, 1),
              block_q=shape.seq_len, block_kv=shape.seq_len)
    if cfg.family == "audio":
        kw["enc_layers"] = depth
    if cfg.ssm is not None:
        kw["ssd_unroll"] = max(shape.seq_len // cfg.ssm.chunk, 1)
    return dataclasses.replace(cfg, **kw)


def probe_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
               seq_shard_decode: bool = True, overrides: dict | None = None,
               tag: str = "") -> dict:
    """Two shallow unrolled compiles (depth = 1x and 2x superblock) at full
    width; linear extrapolation gives exact whole-model HLO flops/bytes and
    collective counts/bytes (layer stacks are homogeneous by construction)."""
    from repro.models.api import _superblock_period
    cfg = get_config(arch)
    if overrides:
        cfg = _apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": cfg.name + tag, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "probe": True}
    ok, why = shape.applicable(cfg)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _write(rec, out_dir, suffix="__probe")
        return rec
    period = _superblock_period(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        points = []
        for depth in (period, 2 * period):
            pcfg = _probe_cfg(cfg, depth, period, shape)
            fn, args = _cell_program(pcfg, shape, mesh, seq_shard_decode)
            with mesh:
                compiled = fn.lower(*args).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            colls = collective_summary(compiled.as_text(),
                                       total_devices=mesh.size)
            points.append({
                "depth": depth,
                "flops": ca.get("flops", 0.0),
                "bytes": ca.get("bytes accessed", 0.0),
                "coll_count": colls.count,
                "coll_operand": colls.operand_bytes,
                "coll_link": colls.link_bytes,
            })
        p1, p2 = points
        blocks = cfg.n_layers // period

        def extrap(key):
            slope = p2[key] - p1[key]           # one superblock's worth
            base = p1[key] - slope              # embed/logits/optimizer
            return max(base + slope * blocks, 0.0), slope, base

        flops, flops_blk, flops_base = extrap("flops")
        byts, _, _ = extrap("bytes")
        cnt, _, _ = extrap("coll_count")
        opnd, _, _ = extrap("coll_operand")
        link, _, _ = extrap("coll_link")
        rec.update({
            "status": "ok", "chips": mesh.size, "points": points,
            "extrapolated_per_device": {
                "flops": flops, "bytes_accessed": byts,
                "coll_count": cnt, "coll_operand_bytes": opnd,
                "coll_link_bytes": link,
                "flops_per_block": flops_blk, "flops_base": flops_base,
            },
        })
    except Exception as e:
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(rec, out_dir, suffix="__probe")
    return rec


def _write(rec: dict, out_dir: str, suffix: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{rec['arch'].replace('/', '_')}__{rec['shape']}"
            f"__{rec['mesh']}{suffix}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--seq-shard-decode", default="true")
    ap.add_argument("--probe", action="store_true",
                    help="cost-probe mode (shallow unrolled compiles)")
    ap.add_argument("--set", default=None,
                    help="config override, e.g. q_head_pad=1 (int values)")
    ap.add_argument("--tag", default="", help="artifact name suffix")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    seq_shard = args.seq_shard_decode.lower() == "true"

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                if args.probe:
                    rec = probe_cell(arch, shape, mesh_kind, args.out,
                                     seq_shard, overrides=_parse_set(args.set),
                                     tag=args.tag)
                else:
                    rec = run_cell(arch, shape, mesh_kind, args.out,
                                   seq_shard, overrides=_parse_set(args.set),
                                   tag=args.tag)
                status = rec["status"]
                extra = (f" compile={rec.get('compile_s', 0):.1f}s"
                         if status == "ok" else
                         f" reason={rec.get('reason', rec.get('error', ''))[:120]}")
                print(f"[dryrun] {arch:24s} {shape:12s} {mesh_kind:6s} "
                      f"{status:8s} ({time.time()-t0:.1f}s){extra}", flush=True)
                results.append(rec)

    failed = [r for r in results if r["status"] == "failed"]
    print(f"\n[dryrun] {len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(failed)} failed")
    if failed:
        for r in failed:
            print(f"  FAILED {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()

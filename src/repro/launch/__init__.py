"""Launchers: production mesh construction, the multi-pod dry-run, roofline
analysis, and the real train/serve drivers."""

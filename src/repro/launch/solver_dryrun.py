import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede all other imports -- see dryrun.py)

"""The paper's own technique at the production mesh: lower + compile
(CA-)BCD/(CA-)BDCD on 256 chips (16x16, flattened 1D layout over both axes)
and 512 chips (2x16x16), and record the collective schedule per s.

This is hillclimb cell 3 ("most representative of the paper's technique"):
the measured table is
    schedule              syncs / H iters     wire bytes / H iters
    unfused variadic s=1        H               H * (b^2+b) w
    unfused variadic s          H/s             (H/s) * (s^2 b^2 + sb) w
    ours fused s                H/s             (H/s) * (s^2 b^2 + sb) w
(the paper's own schedule would be 2 messages per Gram+residual pair; since
PR 3 the unfused baseline packs both operands into one explicit variadic
psum, so only the wire layout differs from the fused packet).  Solvers are
selected from the (formulation, backend) registry via ``lower_solver``.
Usage: PYTHONPATH=src python -m repro.launch.solver_dryrun [--out DIR]
"""
import argparse
import json
import time

from repro.core import FORMULATIONS, count_in_compiled
from repro.core.cost_model import TPU_V5E_ICI, pipeline_schedule
from repro.core.distributed import lower_solver, lower_solver_batched
from repro.launch.mesh import make_production_mesh


def _overlap_fields(mesh, b: int, s: int, tenants: int = 1,
                    formulation: str = "primal") -> dict:
    """Modeled wire-schedule comparison (DESIGN.md section 9) for one cell:
    what the monolithic psum exposes vs what the pipelined ring hides, on the
    ICI machine model at this mesh's axis sizes."""
    d, n = 4096, 1 << 22
    form = formulation if formulation == "dual" else "primal"
    sch = pipeline_schedule(TPU_V5E_ICI, d=d, n=n,
                            axis_sizes=tuple(mesh.shape[a]
                                             for a in mesh.axis_names),
                            b=b, s=s, tenants=tenants, formulation=form)
    return {"modeled_overlap_ratio": sch["overlap_ratio"],
            "modeled_exposed_psum_s": sch["t_exposed_psum"],
            "modeled_exposed_ring_s": sch["t_exposed_ring"],
            "modeled_ring_hops": sch["hops"]}


def run(out_dir: str = "artifacts/solver", impl: str | None = None,
        formulation: str = "primal") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    d, n = 4096, 1 << 22          # dense 4096 x 4.2M f32 panel (64 GiB), abstract
    b, iters = 8, 8
    # The proximal formulation's threshold runs on the replicated post-reduce
    # packet, so its production schedule must be byte-identical to the
    # primal's; lowering it with lam1 > 0 exercises the prox sweep for real.
    solver_kw = {"lam1": 1e-3} if formulation == "proximal" else {}
    for mesh_kind in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        axis = tuple(mesh.axis_names)          # flatten the whole mesh: 1D layout
        for s, fused in ((1, False), (4, False), (4, True), (8, True)):
            if iters % s:
                continue
            t0 = time.time()
            comp = lower_solver(formulation, mesh, d, n, 1e-3, b, s, iters,
                                axis=axis, fuse_packet=fused,
                                unroll=iters // s, impl=impl, **solver_kw)
            cs = count_in_compiled(comp)
            ca = comp.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            rec = {
                "mesh": mesh_kind, "chips": mesh.size, "s": s, "fused": fused,
                "wire": "psum", "formulation": formulation,
                # PacketOperand layout the formulation binds (the dual's
                # "cols" cells lower with NO pre-transpose in the shard body)
                "operand_layout": getattr(FORMULATIONS[formulation],
                                          "operand_layout", "rows"),
                "iters": iters, "collectives": cs.count,
                "operand_bytes": cs.operand_bytes, "link_bytes": cs.link_bytes,
                "flops_per_device": ca.get("flops", 0.0),
                "compile_s": round(time.time() - t0, 1),
                **_overlap_fields(mesh, b, s, formulation=formulation),
            }
            results.append(rec)
            print(f"[solver-dryrun] {mesh_kind} s={s} fused={fused}: "
                  f"{cs.count} collectives / {iters} iters, "
                  f"{cs.operand_bytes:.2e} B wire, "
                  f"compile {rec['compile_s']}s", flush=True)
        # The pipelined backend's ring cell at the best-s point: same packet,
        # the reduction decomposed into collective-permute hops so the next
        # step's Gram contraction overlaps the wire (DESIGN.md section 9).
        s = 8
        t0 = time.time()
        comp = lower_solver(formulation, mesh, d, n, 1e-3, b, s, iters,
                            axis=axis, fuse_packet=True, unroll=iters // s,
                            impl=impl, backend="pipelined", **solver_kw)
        cs = count_in_compiled(comp)
        rec = {
            "mesh": mesh_kind, "chips": mesh.size, "s": s, "fused": True,
            "wire": "ring", "formulation": formulation,
            "operand_layout": getattr(FORMULATIONS[formulation],
                                      "operand_layout", "rows"),
            "iters": iters, "collectives": cs.count,
            "operand_bytes": cs.operand_bytes, "link_bytes": cs.link_bytes,
            "compile_s": round(time.time() - t0, 1),
            **_overlap_fields(mesh, b, s, formulation=formulation),
        }
        results.append(rec)
        print(f"[solver-dryrun] {mesh_kind} s={s} wire=ring: "
              f"{cs.count} collectives / {iters} iters "
              f"(modeled overlap {rec['modeled_overlap_ratio']:.2f}), "
              f"compile {rec['compile_s']}s", flush=True)
    # Keyed by formulation so a proximal dry-run does not clobber the primal
    # artifact ("solver_cells.json" keeps its historical name for primal).
    fname = ("solver_cells.json" if formulation == "primal"
             else f"solver_cells_{formulation}.json")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(results, f, indent=1)
    return results


def run_batched(tenants: int, out_dir: str = "artifacts/solver",
                impl: str | None = None,
                formulation: str = "primal") -> list[dict]:
    """The batched multi-tenant lowering at the production mesh (DESIGN.md
    section 8): T tenant solves, ONE psum per outer step.  Records the
    measured collective schedule (count must equal iters/s regardless of T)
    next to the alpha-beta-gamma model's amortized solves/s and wire
    bytes/iter/tenant, so the dry-run artifact carries both the contract
    and the modeled payoff of the tenant axis."""
    from repro.core.cost_model import (TPU_V5E_ICI, batched_solves_per_second,
                                       tenant_bytes_per_iter)
    os.makedirs(out_dir, exist_ok=True)
    results = []
    d, n = 4096, 1 << 22
    b, iters = 8, 8
    for mesh_kind in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        axis = tuple(mesh.axis_names)
        for s in (1, 4, 8):
            t0 = time.time()
            comp = lower_solver_batched(
                formulation, mesh, d, n, tenants, b, s, iters, axis=axis,
                unroll=iters // s, impl=impl)
            cs = count_in_compiled(comp)
            rec = {
                "mesh": mesh_kind, "chips": mesh.size, "s": s,
                "formulation": formulation, "tenants": tenants,
                "iters": iters, "collectives": cs.count,
                "operand_bytes": cs.operand_bytes, "link_bytes": cs.link_bytes,
                "modeled_solves_per_s": batched_solves_per_second(
                    TPU_V5E_ICI, d=d, n=n, P=mesh.size, b=b, H=iters, s=s,
                    tenants=tenants, formulation=formulation),
                "modeled_bytes_per_iter_per_tenant": tenant_bytes_per_iter(
                    d, n, mesh.size, b, s, tenants, formulation),
                "compile_s": round(time.time() - t0, 1),
                # At serving tenant counts the per-step compute is deep
                # enough to hide most of the ring's wire -- the batched
                # point is where the pipelined schedule pays (section 9).
                **_overlap_fields(mesh, b, s, tenants=tenants,
                                  formulation=formulation),
            }
            results.append(rec)
            print(f"[solver-dryrun] batched {mesh_kind} T={tenants} s={s}: "
                  f"{cs.count} collectives / {iters} iters, "
                  f"{cs.operand_bytes:.2e} B wire, "
                  f"{rec['modeled_solves_per_s']:.1f} modeled solves/s, "
                  f"compile {rec['compile_s']}s", flush=True)
    with open(os.path.join(out_dir,
                           f"solver_cells_batched_T{tenants}.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/solver")
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend: ref | pallas | pallas_interpret")
    ap.add_argument("--formulation", default="primal",
                    help="registry formulation to lower: primal | dual | "
                         "proximal")
    ap.add_argument("--tenants", type=int, default=None,
                    help="lower the BATCHED multi-tenant solve at this "
                         "tenant-axis width instead of the single-solve cells")
    args = ap.parse_args()
    if args.tenants is not None:
        run_batched(args.tenants, args.out, impl=args.impl,
                    formulation=args.formulation)
    else:
        run(args.out, impl=args.impl, formulation=args.formulation)

from .checkpointer import CheckpointManager, CheckpointWriteError

__all__ = ["CheckpointManager", "CheckpointWriteError"]

"""Fault-tolerant checkpointing.

Guarantees (all tested in tests/test_checkpoint.py):
  * **Atomicity** -- a checkpoint directory appears only after a completed
    write (write to ``<step>.tmp`` then os.rename); the LATEST pointer is
    updated with write-temp + rename as well, so a crash mid-save can never
    corrupt the restore path.
  * **Integrity** -- per-leaf CRC32 in the manifest; restore verifies and
    falls back to the next-older checkpoint if any leaf fails (bit-rot /
    truncated write after a node failure).
  * **Exact resume** -- the data-iterator state (and any user extras) ride in
    the manifest, so restart reproduces the exact batch stream.
  * **Elastic restarts** -- arrays are stored with *logical* (unsharded)
    shapes; restore device_puts onto whatever mesh/sharding the new job uses
    (train/elastic.py), so the same checkpoint restarts on a different device
    count.
  * **Async** -- saves run on a writer thread off the training critical path
    (state is device_get'd synchronously -- cheap relative to a step -- and
    serialized in the background).  keep=N pruning runs after each commit.
  * **No silent writer death** -- an exception on the background writer is
    captured and re-raised as :class:`CheckpointWriteError` on the next
    ``save()`` / ``wait()`` / ``close()``, so a failed async snapshot (disk
    full, permissions) can never silently break the restore chain the
    supervisor leans on.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    paths = [str(i) for i in range(len(leaves))]
    return leaves, paths, treedef


class CheckpointWriteError(RuntimeError):
    """A checkpoint write failed.  For async saves this surfaces on the NEXT
    ``save()`` / ``wait()`` / ``close()`` call -- the background thread's
    original exception is chained as ``__cause__``."""


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._writer: threading.Thread | None = None
        self._writer_step: int | None = None
        self._pending_error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, state, extra: dict | None = None,
             block: bool = False) -> None:
        self.wait()  # one in-flight save at a time; raises a captured failure
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        if self.async_save and not block:
            self._writer_step = step
            self._writer = threading.Thread(
                target=self._write_guarded, args=(step, host_state, extra or {}),
                daemon=True)
            self._writer.start()
        else:
            try:
                self._write(step, host_state, extra or {})
            except Exception as e:
                raise CheckpointWriteError(
                    f"checkpoint write for step {step} failed") from e

    def _write_guarded(self, step: int, host_state, extra: dict) -> None:
        # Runs on the writer thread: an uncaught exception here would die with
        # the thread, leaving callers believing the snapshot landed.  Capture
        # it; wait() re-raises on the caller's thread.
        try:
            self._write(step, host_state, extra)
        except BaseException as e:
            self._pending_error = e

    def _raise_pending(self) -> None:
        if self._pending_error is not None:
            e, self._pending_error = self._pending_error, None
            step = self._writer_step
            raise CheckpointWriteError(
                f"async checkpoint write for step {step} failed") from e

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_pending()

    def close(self) -> None:
        """Drain the writer and surface any captured failure.  Call at end of
        job (or use ``wait()``) -- otherwise a failed final snapshot is only
        detected by the next save."""
        self.wait()

    def _write(self, step: int, host_state, extra: dict) -> None:
        leaves, paths, _ = _flatten(host_state)
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for p, leaf in zip(paths, leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{p}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "path": p, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        # atomic LATEST pointer
        ptr_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(ptr_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(ptr_tmp, os.path.join(self.dir, "LATEST"))
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def restore_latest(self, like, shardings=None):
        """Restore the newest valid checkpoint.

        ``like``: a pytree with the target structure (arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        Shardings for elastic placement.  Returns (state, extra, step) or
        None if no valid checkpoint exists.
        """
        for step in reversed(self.all_steps()):
            try:
                return self._restore(step, like, shardings)
            except Exception as e:  # corrupt -> try older
                print(f"[checkpoint] step {step} unusable ({e}); trying older")
        return None

    def _restore(self, step: int, like, shardings):
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        _, paths, treedef = _flatten(like)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        if set(paths) != set(by_path):
            raise ValueError("checkpoint structure mismatch")
        arrays = []
        for p in paths:
            entry = by_path[p]
            arr = np.load(os.path.join(d, entry["file"]))
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != entry["crc"]:
                raise IOError(f"crc mismatch in leaf {p}")
            if arr.dtype.kind == "V":  # bfloat16 etc round-trip as raw void
                import ml_dtypes  # noqa: F401  (registers numpy dtypes)
                arr = arr.view(np.dtype(entry["dtype"]))
            arrays.append(arr)
        state = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, manifest["extra"], step

"""Supervised s-step solves: bounded retry, checkpointed elastic restart.

``solve_supervised`` wraps any registered ``(formulation, backend)`` solver
(the engine registry of ``repro.core.engine``) in a host-side supervision
loop -- the degradation ladder's third rung (DESIGN.md section 7).  The solve
is cut into SEGMENTS of ``ckpt_every`` outer steps; after each segment the
replicated iterate is snapshotted through the existing
:class:`~repro.checkpoint.CheckpointManager` (CRC manifest + atomic rename
for free), and a device loss -- simulated by a ``device_loss``
:class:`~repro.faults.FaultPlan`, raised host-side as
:class:`DeviceLostError` at the segment that contains the injected step --
triggers a bounded-retry restart with exponential backoff: re-plan a 1D mesh
over the survivors (``train.elastic.plan_solver_mesh``), restore the newest
valid snapshot, and resume from its iteration.  Because
``Formulation.pad_shards`` re-pads the LOGICAL operands to any shard count
and the sharded warm start re-derives the device-varying half of the carry
shard-locally, the restarted solve continues on the smaller mesh and
converges to the same answer as the uninterrupted run (tested to 1e-10 in
f64 on even and ragged schedules).

Segment boundaries are multiples of the current ``s``, so the segmented
solve consumes the SAME outer grouping of the index stream as the
uninterrupted solve -- the CA identity is preserved across restarts, and the
only numerical difference is the warm-start re-derivation's rounding.

Guard coupling: every segment runs with the in-scan guard armed by default;
a tripped segment on the sharded backend degrades the REMAINING segments to
``s = 1`` (rung two -- the local backend's engine runs its own in-driver
s=1 tail, see ``engine._degrade_to_s1_tail``).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.core.engine import _resolve_form, get_solver, sample_blocks
from repro.train.elastic import plan_solver_mesh


class DeviceLostError(RuntimeError):
    """A device (shard) dropped out of the solve.  ``survivors`` is the world
    size after the loss; ``at_iter`` the inner iteration the solve had
    reached when it died."""

    def __init__(self, survivors: int, at_iter: int):
        super().__init__(
            f"device lost at inner iteration {at_iter}; "
            f"{survivors} device(s) surviving")
        self.survivors = survivors
        self.at_iter = at_iter


@dataclasses.dataclass
class SupervisedResult:
    w: jax.Array
    alpha: jax.Array
    metrics: dict       # segments / restarts / guard telemetry (host ints)


def solve_supervised(formulation: str, backend: str, X, y, lam: float, b: int,
                     s: int, iters: int, key=None, *, ckpt_dir: str,
                     idx=None, lam1: float | None = None, ckpt_every: int = 2,
                     max_restarts: int = 3, backoff: float = 0.01,
                     mesh=None, axis: str = "shards", fault=None,
                     guard: bool = True, impl: str | None = None,
                     keep: int = 3) -> SupervisedResult:
    """Run a registered solver under supervision (see module docstring).

    Args:
      formulation, backend: engine-registry key (``"primal"`` / ``"dual"`` /
        ``"proximal"`` x ``"local"`` / ``"sharded"``).
      ckpt_dir: snapshot directory for the CheckpointManager (sync writes --
        a segment is not "done" until its snapshot is committed).
      ckpt_every: snapshot cadence in OUTER steps (see
        ``cost_model.snapshot_cadence`` for the principled pick).
      max_restarts: bound on elastic restarts before the loss is re-raised.
      backoff: base seconds of exponential backoff (``backoff * 2**k``).
      fault: optional :class:`~repro.faults.FaultPlan`.  In-scan kinds ride
        into every segment (``step0`` keeps the global outer numbering
        aligned); ``device_loss`` is intercepted HERE and raised as
        :class:`DeviceLostError` when the solve reaches its outer step.
      mesh: starting mesh for the sharded backend (defaults to all devices).
    """
    form = _resolve_form(formulation)
    d, n = X.shape
    if idx is None:
        idx = sample_blocks(key, form.sample_dim(d, n), b, iters)
    if backend == "sharded" and mesh is None:
        mesh = plan_solver_mesh(len(jax.devices()), axis)
    n_shards = (math.prod(mesh.devices.shape) if mesh is not None else 1)
    solve = get_solver(formulation, backend)
    mgr = CheckpointManager(ckpt_dir, keep=keep, async_save=False)

    x0 = None
    i = 0                   # inner iterations completed
    cur_s = s
    segments = restarts = total_trips = 0
    resumed_from = -1
    loss_pending = fault is not None and fault.kind == "device_loss"
    loss_iter = fault.step * s if loss_pending else -1
    w = alpha = None

    while i < iters:
        seg = min(ckpt_every * cur_s, iters - i)
        try:
            if loss_pending and i <= loss_iter < i + seg:
                loss_pending = False
                survivors = (fault.survivors if fault.survivors is not None
                             else max(1, n_shards // 2))
                raise DeviceLostError(survivors, i)
            w, alpha, trips = _run_segment(
                solve, backend, form, X, y, lam, b, cur_s, seg, idx[i:i + seg],
                i // cur_s, x0, mesh=mesh, axis=axis, fault=fault,
                guard=guard, impl=impl, lam1=lam1)
        except DeviceLostError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            time.sleep(backoff * 2 ** (restarts - 1))
            if backend == "sharded":
                n_shards = max(1, e.survivors)
                mesh = plan_solver_mesh(n_shards, axis)
            restored = mgr.restore_latest(like={"x0": jax.ShapeDtypeStruct(
                x0.shape, x0.dtype)} if x0 is not None else None)
            if restored is not None:
                state, extra, _ = restored
                x0 = jax.numpy.asarray(state["x0"])
                i = int(extra["iters_done"])
                cur_s = int(extra["cur_s"])
                resumed_from = i
            else:           # no snapshot yet: cold restart from iteration 0
                x0, i, resumed_from = None, 0, 0
            continue
        segments += 1
        i += seg
        total_trips += trips
        x0 = w if form.operand_layout == "rows" else alpha
        if trips and cur_s > 1 and backend == "sharded":
            cur_s = 1       # rung two for the sharded backend (host-side)
        mgr.save(i, {"x0": x0}, extra={"iters_done": i, "cur_s": cur_s},
                 block=True)
    mgr.close()
    return SupervisedResult(w, alpha, {
        "segments": segments, "restarts": restarts,
        "guard_trips": total_trips, "resumed_from_iter": resumed_from,
        "final_n_shards": n_shards, "final_s": cur_s})


def _run_segment(solve, backend, form, X, y, lam, b, cur_s, seg, seg_idx,
                 step0, x0, *, mesh, axis, fault, guard, impl, lam1):
    """One supervised segment through the registry solver; returns
    ``(w, alpha, trips)`` with ``trips`` a host int."""
    kw = {"idx": seg_idx, "guard": guard, "fault": fault, "step0": step0,
          "impl": impl}
    if lam1 is not None:
        kw["lam1"] = lam1
    if backend == "local":
        if x0 is not None:
            kw["w0" if form.operand_layout == "rows" else "alpha0"] = x0
        res = solve(X, y, lam, b, cur_s, seg, None, **kw)
        trips = (int(jax.device_get(res.metrics["guard_trips"]))
                 if guard else 0)
        return res.w, res.alpha, trips
    out = solve(mesh, X, y, lam, b, cur_s, seg, None, axis=axis, x0=x0, **kw)
    if guard:
        w, alpha, m = out
        return w, alpha, int(jax.device_get(m["guard_trips"]))
    w, alpha = out
    return w, alpha, 0

"""Fault injection + supervised solves for the s-step engine (DESIGN.md
section 7).  ``FaultPlan`` is import-light (tests thread it into every
solver); the supervisor pulls in the checkpoint/elastic stack lazily."""
from .plan import KINDS, FaultPlan

__all__ = ["FaultPlan", "KINDS", "DeviceLostError", "SupervisedResult",
           "solve_supervised"]


def __getattr__(name):
    if name in ("DeviceLostError", "SupervisedResult", "solve_supervised"):
        from . import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Deterministic fault injection for the s-step solvers (test-only hook).

A :class:`FaultPlan` describes ONE fault -- what kind, at which outer step,
on which shard -- and is threaded into the engine's hot loop through
``SolverPlan.fault`` (every solver wrapper and ``lower_solver`` forward a
``fault=`` kwarg).  The two hooks are called at fixed points of
``engine._outer_step``:

* ``apply_packet(Gl, rl, step=, axis=)`` -- corrupt the shard's LOCAL packet
  contribution before the health word is computed, so injected damage is
  visible to the guard exactly the way real damage would be (a NaN-ed
  reduction input, a bit-flipped Gram entry, a zeroed contribution).
* ``apply_health(health, step=, axis=)`` -- corrupt the health word itself;
  only ``drop_shard`` uses it (a dropped worker contributes neither data nor
  presence, so its whole word is zeroed and the reduced presence count comes
  up short -> ``GUARD_SHARD_LOSS``).

Everything is deterministic and trace-friendly: the fault fires when the
traced outer-step index equals ``step`` (and, sharded, when
``lax.axis_index(axis) == shard``), and the bit-flip target entry is drawn
from a seed-keyed ``random.Random`` at TRACE time -- same plan, same fault,
every run.  ``device_loss`` is deliberately inert here: losing a device is
not a wrong number inside the scan, it is the process-level event the
supervisor (``repro.faults.supervisor``) simulates by raising
:class:`~repro.faults.DeviceLostError` at the segment boundary containing
``step`` and restarting on the surviving mesh.
"""
from __future__ import annotations

import dataclasses
import random

import jax
import jax.numpy as jnp

KINDS = ("nan_packet", "bitflip", "drop_shard", "device_loss")

# Bit-flip scale: adding 2^46 * (1 + |x|) to a float perturbs high-exponent
# bits the way a flipped exponent/mantissa-high bit would -- large enough to
# blow the magnitude envelope, finite so the nonfinite guard does NOT fire
# (the two detection paths stay distinguishable in tests).
_BITFLIP_SCALE = 2.0 ** 46


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One injected fault.

    Args:
      kind: one of :data:`KINDS`.
      step: global outer-step index at which the fault fires (``step0``-aware:
        a checkpoint-resumed segment sees the same global numbering).
      shard: target shard for sharded runs (local runs always hit).
      seed: keys the deterministic bit-flip entry choice.
      survivors: for ``device_loss``, the world size after the loss (consumed
        by the supervisor; ``None`` = half the current mesh, at least 1).
    """
    kind: str
    step: int
    shard: int = 0
    seed: int = 0
    survivors: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind={self.kind!r} must be one of {KINDS}")
        if self.step < 0:
            raise ValueError(f"step={self.step} must be >= 0")
        if self.shard < 0:
            raise ValueError(f"shard={self.shard} must be >= 0")

    # ------------------------------------------------------------ hooks --
    def _fire(self, step, axis):
        hit = jnp.asarray(step, jnp.int32) == self.step
        if axis is not None:
            name = axis[0] if isinstance(axis, (tuple, list)) else axis
            hit = hit & (jax.lax.axis_index(name) == self.shard)
        return hit

    def apply_packet(self, Gl, rl, *, step, axis):
        if self.kind == "nan_packet":
            fire = self._fire(step, axis)
            bad = jnp.asarray(jnp.nan, Gl.dtype)
            return (jnp.where(fire, jnp.full_like(Gl, bad), Gl),
                    jnp.where(fire, jnp.full_like(rl, bad), rl))
        if self.kind == "bitflip":
            fire = self._fire(step, axis)
            rng = random.Random(f"{self.seed}:{Gl.shape}")
            i = rng.randrange(Gl.shape[0])
            j = rng.randrange(Gl.shape[1])
            entry = Gl[i, j]
            flipped = entry + jnp.asarray(_BITFLIP_SCALE, Gl.dtype) * (
                1 + jnp.abs(entry))
            return Gl.at[i, j].set(jnp.where(fire, flipped, entry)), rl
        if self.kind == "drop_shard":
            fire = self._fire(step, axis)
            return (jnp.where(fire, jnp.zeros_like(Gl), Gl),
                    jnp.where(fire, jnp.zeros_like(rl), rl))
        return Gl, rl            # device_loss: supervisor-level, inert here

    def apply_health(self, health, *, step, axis):
        if self.kind == "drop_shard":
            fire = self._fire(step, axis)
            return jnp.where(fire, jnp.zeros_like(health), health)
        return health

"""Pallas TPU kernels for the paper's compute hot spots.

gram/      -- the sb x sb Gram packet (the BLAS-3 core of CA-BCD/CA-BDCD)
blocksolve/ -- the s-step block forward-substitution sweep
Each kernel ships <name>_kernel.py (pallas_call + BlockSpec), ops.py (jit'd
dispatch with padding), ref.py (pure-jnp oracle).
"""

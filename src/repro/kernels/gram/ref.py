"""Pure-jnp oracles for the Gram kernels.  These define correctness; the
Pallas kernels are validated against them (interpret mode) across a
shape/dtype sweep in tests/test_kernels.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(A: jax.Array, scale: float = 1.0, reg: float = 0.0) -> jax.Array:
    """G = scale * A @ A^T + reg * I, accumulated in f32 (matching the MXU)."""
    acc = jnp.float32 if A.dtype != jnp.float64 else jnp.float64
    G = jnp.einsum("ik,jk->ij", A, A, preferred_element_type=acc)
    G = scale * G + reg * jnp.eye(A.shape[0], dtype=acc)
    return G.astype(acc)


def gram_packet_ref(A: jax.Array, u: jax.Array, scale: float = 1.0,
                    reg: float = 0.0, scale_r: float | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Fused outer-iteration packet: (G, r) = (scale*AA^T + reg*I, scale_r*A@u).

    One pass over A produces both the sb x sb Gram and the sb residual vector
    -- the compute-side twin of the fused one-all-reduce packet in
    repro.core.distributed.  ``scale_r`` defaults to ``scale``; the dual
    solvers use ``scale_r=1`` (raw Y^T w) with the 1/(lam n^2) Gram scale.
    """
    acc = jnp.float32 if A.dtype != jnp.float64 else jnp.float64
    sr = scale if scale_r is None else scale_r
    G = gram_ref(A, scale, reg)
    r = sr * jnp.einsum("ik,k->i", A, u, preferred_element_type=acc)
    return G, r.astype(acc)


def gram_packet_sampled_ref(X: jax.Array, flat: jax.Array, u: jax.Array,
                            scale: float = 1.0, reg: float = 0.0,
                            scale_r: float | None = None
                            ) -> tuple[jax.Array, jax.Array]:
    """Sampled packet oracle: ``gram_packet_ref(X[flat, :], u)``.  The gather
    is internal to the backend -- the solvers never materialize the panel --
    and XLA fuses it into the contraction on the ref path."""
    return gram_packet_ref(X[flat, :], u, scale, reg, scale_r)


def gram_packet_sampled_cols_ref(X: jax.Array, flat: jax.Array, u: jax.Array,
                                 scale: float = 1.0, reg: float = 0.0,
                                 scale_r: float | None = None
                                 ) -> tuple[jax.Array, jax.Array]:
    """Column-sampled packet oracle: ``gram_packet_ref(X[:, flat].T, u)`` --
    the dual layout's (G, r) = (scale * Y^T Y + reg*I, scale_r * Y^T u) for
    Y = X[:, flat], straight from the original (d, n) array."""
    return gram_packet_ref(X[:, flat].T, u, scale, reg, scale_r)


def panel_apply_cols_ref(X: jax.Array, flat: jax.Array, v: jax.Array,
                         scale: float = 1.0) -> jax.Array:
    """out(d) = scale * X[:, flat] @ v -- the dual's deferred update from the
    original layout (``w -= Y das / (lam n)`` with Y = X[:, flat])."""
    acc = jnp.float32 if X.dtype != jnp.float64 else jnp.float64
    out = scale * jnp.einsum("km,m->k", X[:, flat], v,
                             preferred_element_type=acc)
    return out.astype(acc)


def panel_apply_ref(X: jax.Array, flat: jax.Array, v: jax.Array,
                    scale: float = 1.0) -> jax.Array:
    """out(n) = scale * X[flat, :]^T v -- the deferred vector updates
    (``alpha += Y^T dws`` / ``wl -= Yl das``) from X + indices."""
    acc = jnp.float32 if X.dtype != jnp.float64 else jnp.float64
    out = scale * jnp.einsum("mk,m->k", X[flat, :], v,
                             preferred_element_type=acc)
    return out.astype(acc)


def panel_matvec_cols_ref(X: jax.Array, flat: jax.Array, t: jax.Array,
                          scale: float = 1.0) -> jax.Array:
    """out(m) = scale * X[:, flat]^T t -- the dual residual direction from
    the original layout, written as the EXACT expression of the fused
    packet's r (``gram_packet_sampled_cols_ref``'s einsum on the transposed
    panel) so standalone and fused residuals agree bitwise on ref."""
    acc = jnp.float32 if X.dtype != jnp.float64 else jnp.float64
    out = scale * jnp.einsum("ik,k->i", X[:, flat].T, t,
                             preferred_element_type=acc)
    return out.astype(acc)


def panel_matvec_ref(X: jax.Array, flat: jax.Array, t: jax.Array,
                     scale: float = 1.0) -> jax.Array:
    """out(m) = scale * X[flat, :] t (the residual direction)."""
    acc = jnp.float32 if X.dtype != jnp.float64 else jnp.float64
    out = scale * jnp.einsum("mk,k->m", X[flat, :], t,
                             preferred_element_type=acc)
    return out.astype(acc)

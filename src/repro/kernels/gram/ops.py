"""Public ops for the Gram packet: pad-to-tile, backend dispatch, unpad.

``gram_packet(A, u)`` is the entry point the solvers call.  On TPU it runs the
Pallas kernel; everywhere else (this CPU container, and inside the dry-run
lowering) it uses the jnp reference, which XLA fuses well.  ``impl`` can force
either path; tests force ``impl="pallas_interpret"`` to execute the kernel
body in Python on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .gram_kernel import DEFAULT_BK, DEFAULT_BM, gram_packet_pallas


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def gram_packet(A: jax.Array, u: jax.Array, *, scale: float = 1.0,
                reg: float = 0.0, impl: str | None = None,
                bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                symmetric_skip: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused (G, r) = (scale*A@A^T + reg*I, scale*A@u); A (m, n), u (n,).

    Zero padding is exact: padded k-columns contribute 0 to both products and
    padded m-rows are sliced off (their diagonal reg never leaves the pad).
    """
    impl = impl or _auto_impl()
    if impl == "ref":
        return ref.gram_packet_ref(A, u, scale, reg)
    m, n = A.shape
    # Pick tile sizes that do not exceed the (padded) operand.
    bm_eff = min(bm, _round_up(m, 8))
    bk_eff = min(bk, _round_up(n, 128))
    Ap = _pad_axis(_pad_axis(A, bm_eff, 0), bk_eff, 1)
    up = _pad_axis(u, bk_eff, 0)
    G, r = gram_packet_pallas(
        Ap, up, scale=scale, reg=reg, bm=bm_eff, bk=bk_eff,
        symmetric_skip=symmetric_skip,
        interpret=(impl == "pallas_interpret"))
    return G[:m, :m], r[:m]


def gram(A: jax.Array, *, scale: float = 1.0, reg: float = 0.0,
         impl: str | None = None, **kw) -> jax.Array:
    """G = scale * A @ A^T + reg * I (Gram only; u path fed zeros)."""
    impl = impl or _auto_impl()
    if impl == "ref":
        return ref.gram_ref(A, scale, reg)
    G, _ = gram_packet(A, jnp.zeros((A.shape[1],), A.dtype), scale=scale,
                       reg=reg, impl=impl, **kw)
    return G


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult

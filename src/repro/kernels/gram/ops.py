"""Public ops for the Gram packet: pad-to-tile, backend dispatch, unpad.

This is the Gram-backend dispatch layer: every Gram-shaped product in the
solvers goes through it (re-exported as ``repro.core.gram_packet`` etc.).  On
TPU it runs the Pallas kernels; everywhere else (this CPU container, and
inside the dry-run lowering) it uses the jnp reference, which XLA fuses well.
``impl`` can force either path; tests force ``impl="pallas_interpret"`` to
execute the kernel bodies on CPU and assert solver-level equivalence against
``impl="ref"``.

Entry points:

* ``gram_packet(A, u)`` -- fused (G, r) on a pre-materialized operand (kept
  for callers that already hold the panel, e.g. TSQR's stacked R factors).
* ``gram_packet_sampled(X, flat, u)`` -- the panel-free hot path: the same
  packet for the sampled panel ``Y`` without materializing Y.  ``X`` is a
  :mod:`~repro.kernels.gram.operands` PacketOperand (row-major /
  column-major / pre-materialized -- the operand owns the gather strategy)
  or a raw array, which means row-major: ``Y = X[flat, :]``.  All solver
  formulations build their packets here through the operand their
  ``bind``/``bind_shard`` produced.
* ``panel_apply(X, flat, v)`` / ``panel_matvec(X, flat, t)`` -- the deferred
  vector updates (``alpha += Y^T dws``, ``w -= Y das``) and the sample-side
  matvec, also panel-free and also operand-dispatched.
* ``gram(A)`` -- Gram only, dispatched to a residual-free kernel (the packet
  kernel is never fed a zeros u).
* ``normal_matvec(X, v)`` -- the CG normal-equations operator
  ``scale * X X^T v + lam v`` as two streaming panel products.

Tile sizes: callers may pin ``bm``/``bk``; otherwise ``tuning.pick_tiles``
consults the autotuned (sb, n, dtype, layout) table populated by
``benchmarks/gram_autotune.py`` and falls back to the layout's heuristic.

Knob threading: callers that issue several packet calls with the same
backend/tile choices (the solver engine) carry ONE :class:`PacketPlan` and
pass it as ``plan=`` instead of re-threading ``impl``/``bm``/``bk`` through
every signature.  Explicitly-passed knobs win over the plan's, so a plan acts
as a bundle of defaults (DESIGN.md section 5.4).
"""
from __future__ import annotations

import dataclasses
import operator

import jax
import jax.numpy as jnp

from . import ref
from .gram_kernel import gram_packet_pallas, gram_pallas
from .operands import _pad_axis, as_operand, resolve_tiles

_IMPLS = ("ref", "pallas", "pallas_interpret")


@dataclasses.dataclass(frozen=True)
class PacketPlan:
    """One bundle of kernel-dispatch knobs for a sequence of packet calls.

    ``impl`` selects the backend (``None`` auto-selects per JAX backend);
    ``bm``/``bk`` pin the kernel tiles (``None`` consults the tuning table).
    The solver engine builds one plan per solve and hands it to every
    ``gram_packet_sampled`` / ``panel_apply`` call in the hot loop, replacing
    the per-call ``impl=``/``tiles=`` threading of PRs 1-2.

    Knobs are validated here, at construction: a typo'd ``impl`` or a
    zero/negative tile fails fast with the accepted set instead of erroring
    at the first kernel call inside a jitted scan.
    """
    impl: str | None = None
    bm: int | None = None
    bk: int | None = None

    def __post_init__(self):
        if self.impl is not None:
            _check_impl(self.impl)
        for name in ("bm", "bk"):
            _check_tile(name, getattr(self, name))

    @classmethod
    def make(cls, impl: str | None = None,
             tiles: tuple[int, int] | None = None) -> "PacketPlan":
        """Build from the public solver knobs (``impl``, ``tiles=(bm, bk)``)."""
        if tiles is None:
            return cls(impl=impl)
        if len(tiles) != 2:
            raise ValueError(f"tiles={tiles!r} must be a (bm, bk) pair")
        return cls(impl=impl, bm=tiles[0], bk=tiles[1])


def _check_positive_int(name: str, v) -> None:
    """Shared fail-fast knob check (PacketPlan tiles, SolverPlan b/s/unroll):
    ints and numpy integers >= 1; bools and floats rejected."""
    try:
        iv = operator.index(v)
    except TypeError:
        iv = None
    if isinstance(v, bool) or iv is None or iv < 1:
        raise ValueError(f"{name}={v!r} must be a positive int")


def _check_tile(name: str, v) -> None:
    """Tiles are positive ints or None (= consult the tuning table); 0 is an
    error, not "unset" -- it used to falsy-fall-through to the plan's tiles."""
    if v is not None:
        _check_positive_int(f"kernel tile {name}", v)


def _with_plan(plan: PacketPlan | None, impl, bm, bk):
    """Resolve per-call knobs against the plan: explicitly-passed arguments
    win; only ``None`` means "defer to the plan" (``bm=0`` raises rather than
    silently resolving to the plan's tile)."""
    _check_tile("bm", bm)
    _check_tile("bk", bk)
    if plan is None:
        return impl, bm, bk
    return (impl if impl is not None else plan.impl,
            bm if bm is not None else plan.bm,
            bk if bk is not None else plan.bk)


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _check_impl(impl: str) -> None:
    # Called before the ref/kernel branch in every entry point (and at
    # PacketPlan construction), so the listed set is the true accepted set.
    if impl not in _IMPLS:
        raise ValueError(
            f"unknown gram impl {impl!r}; expected one of {_IMPLS}")


def _resolve(plan, impl, bm, bk) -> tuple[str, int | None, int | None]:
    impl, bm, bk = _with_plan(plan, impl, bm, bk)
    impl = impl or _auto_impl()
    _check_impl(impl)
    return impl, bm, bk


def _tiles(m: int, n: int, dtype, bm: int | None, bk: int | None
           ) -> tuple[int, int]:
    """(bm, bk) for a materialized row-major operand: the operand layer's
    shared clamp rule at layout="rows"."""
    return resolve_tiles(m, n, dtype, bm, bk, "rows")


def gram_packet(A: jax.Array, u: jax.Array, *, scale: float = 1.0,
                reg: float = 0.0, scale_r: float | None = None,
                impl: str | None = None,
                bm: int | None = None, bk: int | None = None,
                symmetric_skip: bool = True,
                plan: PacketPlan | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused (G, r) = (scale*A@A^T + reg*I, scale_r*A@u); A (m, n), u (n,).

    ``scale_r`` defaults to ``scale``.  ``impl`` is one of ``"ref"`` (jnp,
    XLA-fused), ``"pallas"`` (TPU kernel), ``"pallas_interpret"`` (kernel body
    executed on CPU, the test path); ``None`` auto-selects per backend.
    ``bm``/``bk`` default to the tuning-table pick for (m, n, dtype).

    Zero padding is exact: padded k-columns contribute 0 to both products and
    padded m-rows are sliced off (their diagonal reg never leaves the pad).
    """
    impl, bm, bk = _resolve(plan, impl, bm, bk)
    if impl == "ref":
        return ref.gram_packet_ref(A, u, scale, reg, scale_r)
    m, n = A.shape
    bm_eff, bk_eff = _tiles(m, n, A.dtype, bm, bk)
    Ap = _pad_axis(_pad_axis(A, bm_eff, 0), bk_eff, 1)
    up = _pad_axis(u, bk_eff, 0)
    G, r = gram_packet_pallas(
        Ap, up, scale=scale, reg=reg, scale_r=scale_r, bm=bm_eff, bk=bk_eff,
        symmetric_skip=symmetric_skip,
        interpret=(impl == "pallas_interpret"))
    return G[:m, :m], r[:m]


def gram_packet_sampled(X, flat: jax.Array, u: jax.Array, *,
                        scale: float = 1.0, reg: float = 0.0,
                        scale_r: float | None = None, impl: str | None = None,
                        bm: int | None = None, bk: int | None = None,
                        symmetric_skip: bool = True,
                        plan: PacketPlan | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Panel-free packet: (G, r) = (scale*Y Y^T + reg*I, scale_r*Y u) for the
    operand's sampled panel Y *without materializing Y*.  ``X`` is a
    PacketOperand or a raw (d, n) array (row-major: ``Y = X[flat, :]``);
    ``flat`` (m,) int indices (duplicates allowed), ``u`` of the operand's
    contraction length.

    The operand owns the gather: row-major scalar-prefetches ``flat`` and
    streams sampled rows HBM->VMEM inside the kernel; column-major gathers
    lane-aligned column tiles of the original layout; materialized operands
    gather the already-formed products.  Padding is exact in every layout
    (padded contraction entries are zero and padded index slots only touch
    G/r rows >= m, which are sliced off before the regularized diagonal can
    leak).
    """
    impl, bm, bk = _resolve(plan, impl, bm, bk)
    return as_operand(X).packet(flat, u, scale=scale, reg=reg,
                                scale_r=scale_r, impl=impl, bm=bm, bk=bk,
                                symmetric_skip=symmetric_skip)


def panel_apply(X, flat: jax.Array, v: jax.Array, *,
                scale: float = 1.0, impl: str | None = None,
                bm: int | None = None, bk: int | None = None,
                plan: PacketPlan | None = None) -> jax.Array:
    """out = scale * Y^T v for the operand's sampled panel, panel-free: the
    deferred vector updates (``alpha += Y^T dws`` primal, ``w -= Y das``
    dual).  Output length is the operand's contraction dimension.  Padded
    index slots carry v == 0, so their gathered panel rows contribute 0."""
    impl, bm, bk = _resolve(plan, impl, bm, bk)
    return as_operand(X).apply(flat, v, scale=scale, impl=impl, bm=bm, bk=bk)


def panel_matvec(X, flat: jax.Array, t: jax.Array, *,
                 scale: float = 1.0, impl: str | None = None,
                 bm: int | None = None, bk: int | None = None,
                 plan: PacketPlan | None = None) -> jax.Array:
    """out(m) = scale * Y t, panel-free (the residual direction)."""
    impl, bm, bk = _resolve(plan, impl, bm, bk)
    return as_operand(X).matvec(flat, t, scale=scale, impl=impl, bm=bm, bk=bk)


def normal_matvec(X: jax.Array, v: jax.Array, *, lam: float = 0.0,
                  scale: float = 1.0, impl: str | None = None,
                  bm: int | None = None, bk: int | None = None,
                  plan: PacketPlan | None = None) -> jax.Array:
    """(scale * X X^T + lam I) v as two streaming panel products -- the CG
    normal-equations operator (``core/krylov.py``), never a d x d matrix.

    Unlike the sampled packets, ``impl=None`` stays on the ref path on every
    backend: this is a dense matvec, which XLA's native matmul already
    schedules well on TPU, and routing it through the identity-index row-DMA
    kernels by default would handicap the CG baseline the solvers are
    compared against.  The kernel route is opt-in via an explicit ``impl``.
    """
    impl, bm, bk = _with_plan(plan, impl, bm, bk)
    impl = impl or "ref"
    _check_impl(impl)
    if impl == "ref":
        return X @ (X.T @ v) * scale + lam * v
    d = X.shape[0]
    rows = jnp.arange(d, dtype=jnp.int32)
    t = panel_apply(X, rows, v, impl=impl, bm=bm, bk=bk)          # X^T v
    out = panel_matvec(X, rows, t.astype(X.dtype), scale=scale, impl=impl,
                       bm=bm, bk=bk)                              # X (X^T v)
    return out + lam * v


def gram(A: jax.Array, *, scale: float = 1.0, reg: float = 0.0,
         impl: str | None = None, bm: int | None = None,
         bk: int | None = None, symmetric_skip: bool = True,
         plan: PacketPlan | None = None) -> jax.Array:
    """G = scale * A @ A^T + reg * I, via the residual-free Gram kernel (the
    packet kernel's u path is never fed, computed, or written)."""
    impl, bm, bk = _resolve(plan, impl, bm, bk)
    if impl == "ref":
        return ref.gram_ref(A, scale, reg)
    m, n = A.shape
    bm_eff, bk_eff = _tiles(m, n, A.dtype, bm, bk)
    Ap = _pad_axis(_pad_axis(A, bm_eff, 0), bk_eff, 1)
    G = gram_pallas(Ap, scale=scale, reg=reg, bm=bm_eff, bk=bk_eff,
                    symmetric_skip=symmetric_skip,
                    interpret=(impl == "pallas_interpret"))
    return G[:m, :m]

"""Public ops for the Gram packet: pad-to-tile, backend dispatch, unpad.

``gram_packet(A, u)`` is the Gram-backend dispatch layer: every Gram + residual
pair in the solvers goes through it -- the ``Y @ Y.T`` / ``Xb @ Xb.T`` products
in ``repro.core.bcd`` / ``repro.core.bdcd`` and the local (Gl, rl)
contributions inside ``shard_map`` in ``repro.core.distributed`` (re-exported
as ``repro.core.gram_packet``).  On TPU it runs the Pallas kernel; everywhere
else (this CPU container, and inside the dry-run lowering) it uses the jnp
reference, which XLA fuses well.  ``impl`` can force either path; tests force
``impl="pallas_interpret"`` to execute the kernel body on CPU and assert
solver-level equivalence against ``impl="ref"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .gram_kernel import DEFAULT_BK, DEFAULT_BM, gram_packet_pallas


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def gram_packet(A: jax.Array, u: jax.Array, *, scale: float = 1.0,
                reg: float = 0.0, scale_r: float | None = None,
                impl: str | None = None,
                bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                symmetric_skip: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused (G, r) = (scale*A@A^T + reg*I, scale_r*A@u); A (m, n), u (n,).

    ``scale_r`` defaults to ``scale``.  ``impl`` is one of ``"ref"`` (jnp,
    XLA-fused), ``"pallas"`` (TPU kernel), ``"pallas_interpret"`` (kernel body
    executed on CPU, the test path); ``None`` auto-selects per backend.

    Zero padding is exact: padded k-columns contribute 0 to both products and
    padded m-rows are sliced off (their diagonal reg never leaves the pad).
    """
    impl = impl or _auto_impl()
    if impl == "ref":
        return ref.gram_packet_ref(A, u, scale, reg, scale_r)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(
            f"unknown gram impl {impl!r}; expected one of "
            "('ref', 'pallas', 'pallas_interpret')")
    m, n = A.shape
    # Pick tile sizes that do not exceed the (padded) operand.
    bm_eff = min(bm, _round_up(m, 8))
    bk_eff = min(bk, _round_up(n, 128))
    Ap = _pad_axis(_pad_axis(A, bm_eff, 0), bk_eff, 1)
    up = _pad_axis(u, bk_eff, 0)
    G, r = gram_packet_pallas(
        Ap, up, scale=scale, reg=reg, scale_r=scale_r, bm=bm_eff, bk=bk_eff,
        symmetric_skip=symmetric_skip,
        interpret=(impl == "pallas_interpret"))
    return G[:m, :m], r[:m]


def gram(A: jax.Array, *, scale: float = 1.0, reg: float = 0.0,
         impl: str | None = None, **kw) -> jax.Array:
    """G = scale * A @ A^T + reg * I (Gram only; u path fed zeros)."""
    impl = impl or _auto_impl()
    if impl == "ref":
        return ref.gram_ref(A, scale, reg)
    G, _ = gram_packet(A, jnp.zeros((A.shape[1],), A.dtype), scale=scale,
                       reg=reg, impl=impl, **kw)
    return G


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult

"""Column-tile sampled Pallas kernels: the dual layout without the pre-transpose.

The dual methods (Algorithms 3/4) sample *columns* of X.  Until PR 5 the
solvers faked that by materializing ``XT = X.T`` once per solve, turning
column sampling into the row-sampled kernels of ``sampled_kernel.py`` at the
cost of a second resident copy of the dataset for the whole solve.  The
kernels here gather column tiles of the ORIGINAL (d, n) layout instead:

* ``gram_packet_sampled_cols_pallas``: the fused dual packet
  ``(G = scale * Y^T Y + reg*I, r = scale_r * Y^T u)`` for ``Y = X[:, flat]``
  -- same output contract as the row-sampled packet on ``X.T``, zero extra
  resident copy.
* ``panel_apply_cols_pallas``: the deferred dual update
  ``out(d) = scale * X[:, flat] @ v`` (Eq. 15/19's ``w -= Y das / (lam n)``).
* ``panel_matvec_cols_pallas``: the standalone residual direction
  ``out(m) = scale * X[:, flat]^T t`` -- the batched multi-tenant engine's
  per-tenant residual, accumulated tile-for-tile like the fused packet's
  ``r`` cells so a shared-Gram batched solve reproduces the single-solve
  residual bitwise (DESIGN.md section 8).

Gather strategy (lane-aligned column DMA): a raw column copy would move bk
words with stride n -- 4-byte bursts the TPU DMA engines serialize.  Instead
each sampled column ``c = flat[a]`` is fetched as the lane-aligned slab
``X[k*bk:(k+1)*bk, (c//LANE)*LANE : +LANE]`` -- contiguous 128-lane rows, the
same burst shape as the row kernel's copies -- and the target column
``c % LANE`` is selected out of the slab in VMEM (one-hot mask + lane-sum,
no arithmetic on the values, so the extracted panel is bitwise the gathered
column).  The slab fetch over-reads by the lane width: LANE x the useful
column bytes, the per-iteration traffic this layout trades for dropping the
2x resident footprint (``cost_model.packet_hbm_bytes(layout="cols")`` carries
the term; sampled columns sharing a lane group are NOT deduplicated -- the
model is the worst case).

Grid/tiling mirrors ``sampled_kernel.py`` with the contraction running over
X's ROWS (d): grid = (m/bm, m/bm, d/bk) with k innermost, symmetric skip +
mirror, reg fused on the last k step.  The extracted panels are (bm, bk) --
sampled column a as row a, restricted to the k-th row tile of X -- so the
MXU contractions are the row kernel's, verbatim.  Default tiles are smaller
than the row kernel's (the slab scratch is LANE x a panel): at
(bm=8, bk=256, f32) VMEM holds 2 * (8*256*128)*4B of slabs + 2 * (8*256)*4B
of panels ~= 2.1 MiB.

Requires m % bm == 0, d % bk == 0, n % LANE == 0 (the operand layer pads;
padded index slots point at column 0 and only touch G/r rows >= m, padded
d rows of X are zero so they contribute nothing to the contraction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gram_kernel import _add_diag_reg, mirror_lower

LANE = 128            # lane width of the aligned slab copies
DEFAULT_BM_COLS = 8   # G tile edge (sampled columns per block)
DEFAULT_BK_COLS = 256 # contraction tile over d (X's rows)


def _gather_cols(idx_ref, x_ref, panel, slabs, sems, base, k,
                 bm: int, bk: int):
    """Fetch columns ``X[k*bk:(k+1)*bk, idx_ref[base+a]] -> panel[a]`` for
    a < bm via lane-aligned slab DMAs: start all bm slab copies on per-slot
    semaphores, then drain each and select its target lane into the panel."""

    def _copy(a):
        group = (idx_ref[base + a] // LANE) * LANE
        return pltpu.make_async_copy(
            x_ref.at[pl.ds(k * bk, bk), pl.ds(group, LANE)],
            slabs.at[a], sems.at[a])

    def _start(a, _):
        _copy(a).start()
        return 0

    def _extract(a, _):
        _copy(a).wait()
        col = idx_ref[base + a] % LANE
        slab = slabs[pl.ds(a, 1)][0]                     # (bk, LANE)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (bk, LANE), 1)
        # One-hot select: x + 0 is exact, so panel row a IS column `col`.
        sel = jnp.sum(jnp.where(lanes == col, slab, jnp.zeros_like(slab)),
                      axis=1)
        panel[pl.ds(a, 1), :] = sel[None, :]
        return 0

    jax.lax.fori_loop(0, bm, _start, 0)
    jax.lax.fori_loop(0, bm, _extract, 0)


def _sampled_cols_packet_kernel(idx_ref, x_ref, u_ref, g_ref, r_ref, yi, yj,
                                slab_i, slab_j, sem_i, sem_j, *, scale: float,
                                reg: float, scale_r: float, n_k: int, bm: int,
                                bk: int, symmetric_skip: bool):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    acc = g_ref.dtype

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(jnp.logical_and(k == 0, j == 0))
    def _init_r():
        r_ref[...] = jnp.zeros_like(r_ref)

    compute = jnp.logical_or(j <= i, jnp.logical_not(symmetric_skip))

    @pl.when(compute)
    def _gather_i():
        _gather_cols(idx_ref, x_ref, yi, slab_i, sem_i, i * bm, k, bm, bk)

    @pl.when(jnp.logical_and(compute, i != j))
    def _gather_j():
        _gather_cols(idx_ref, x_ref, yj, slab_j, sem_j, j * bm, k, bm, bk)

    @pl.when(compute)
    def _accumulate():
        a_i = yi[...]
        a_j = jnp.where(i == j, yi[...], yj[...])
        g_ref[...] += scale * jax.lax.dot_general(
            a_i, a_j, (((1,), (1,)), ((), ())),
            preferred_element_type=acc)

    # r = scale_r * Y^T u rides on the j == 0 cells (u tiled over d).
    @pl.when(j == 0)
    def _residual():
        u = u_ref[...]
        r_ref[...] += scale_r * jax.lax.dot_general(
            yi[...], u[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=acc)[:, 0]

    @pl.when(jnp.logical_and(k == n_k - 1, i == j))
    def _reg():
        _add_diag_reg(g_ref, reg)


@functools.partial(jax.jit, static_argnames=("scale", "reg", "scale_r", "bm",
                                             "bk", "symmetric_skip",
                                             "interpret"))
def gram_packet_sampled_cols_pallas(X: jax.Array, flat: jax.Array,
                                    u: jax.Array, *, scale: float = 1.0,
                                    reg: float = 0.0,
                                    scale_r: float | None = None,
                                    bm: int = DEFAULT_BM_COLS,
                                    bk: int = DEFAULT_BK_COLS,
                                    symmetric_skip: bool = True,
                                    interpret: bool = False
                                    ) -> tuple[jax.Array, jax.Array]:
    """(G, r) = (scale * Y^T Y + reg*I, scale_r * Y^T u) for Y = X[:, flat],
    gathered from the original (d, n) layout.  X (d, n) with d % bk == 0 and
    n % LANE == 0, flat (m,) int32 with m % bm == 0, u (d,)."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or d % bk or n % LANE:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}, "
            f"LANE={LANE}")
    n_k = d // bk
    grid = (m // bm, m // bm, n_k)
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(
        _sampled_cols_packet_kernel, scale=scale, reg=reg,
        scale_r=(scale if scale_r is None else scale_r), n_k=n_k, bm=bm,
        bk=bk, symmetric_skip=symmetric_skip)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # flat -> SMEM, pre-grid
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bk,), lambda i, j, k, idx: (k,)),       # u tile (d)
        ],
        out_specs=[
            pl.BlockSpec((bm, bm), lambda i, j, k, idx: (i, j)),  # G tile
            pl.BlockSpec((bm,), lambda i, j, k, idx: (i,)),       # r tile
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),           # extracted row panel i
            pltpu.VMEM((bm, bk), X.dtype),           # extracted row panel j
            pltpu.VMEM((bm, bk, LANE), X.dtype),     # slabs for panel i
            pltpu.VMEM((bm, bk, LANE), X.dtype),     # slabs for panel j
            pltpu.SemaphoreType.DMA((bm,)),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    g, r = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, m), acc),
            jax.ShapeDtypeStruct((m,), acc),
        ],
        interpret=interpret,
    )(flat, X, u)

    if symmetric_skip:
        g = mirror_lower(g, bm)
    return g, r


def _panel_matvec_cols_kernel(idx_ref, x_ref, t_ref, o_ref, ybuf, slabs, sems,
                              *, scale: float, bm: int, bk: int):
    i, k = pl.program_id(0), pl.program_id(1)
    acc = o_ref.dtype

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _gather_cols(idx_ref, x_ref, ybuf, slabs, sems, i * bm, k, bm, bk)
    # Same contraction cell as the fused packet's residual (j == 0 cells of
    # _sampled_cols_packet_kernel), accumulated in the same k order, so this
    # standalone matvec is bitwise the fused r when tiles match.
    o_ref[...] += scale * jax.lax.dot_general(
        ybuf[...], t_ref[...][:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=acc)[:, 0]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "interpret"))
def panel_matvec_cols_pallas(X: jax.Array, flat: jax.Array, t: jax.Array, *,
                             scale: float = 1.0, bm: int = DEFAULT_BM_COLS,
                             bk: int = DEFAULT_BK_COLS,
                             interpret: bool = False) -> jax.Array:
    """out(m) = scale * X[:, flat]^T t from the original (d, n) layout -- the
    dual residual direction as a standalone kernel.  Grid (m/bm, d/bk) with
    the contraction (k over d) innermost so each output tile accumulates in
    VMEM exactly like the fused packet's r tiles."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or d % bk or n % LANE:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}, "
            f"LANE={LANE}")
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(_panel_matvec_cols_kernel, scale=scale, bm=bm,
                               bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // bm, d // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bk,), lambda i, k, idx: (k,)),          # t tile (d)
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, k, idx: (i,)),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),
            pltpu.VMEM((bm, bk, LANE), X.dtype),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), acc),
        interpret=interpret,
    )(flat, X, t)


def _panel_apply_cols_kernel(idx_ref, x_ref, v_ref, o_ref, ybuf, slabs, sems,
                             *, scale: float, bm: int, bk: int):
    k, t = pl.program_id(0), pl.program_id(1)
    acc = o_ref.dtype

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _gather_cols(idx_ref, x_ref, ybuf, slabs, sems, t * bm, k, bm, bk)
    o_ref[...] += scale * jax.lax.dot_general(
        ybuf[...], v_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "interpret"))
def panel_apply_cols_pallas(X: jax.Array, flat: jax.Array, v: jax.Array, *,
                            scale: float = 1.0, bm: int = DEFAULT_BM_COLS,
                            bk: int = DEFAULT_BK_COLS,
                            interpret: bool = False) -> jax.Array:
    """out(d) = scale * X[:, flat] @ v from the original layout -- the dual's
    deferred ``w -= Y das / (lam n)`` without a pre-transposed operand.  Grid
    (d/bk, m/bm) with the sampled-column tiles innermost so each output tile
    accumulates in VMEM; padded index slots must carry v == 0 (the operand
    layer guarantees this)."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or d % bk or n % LANE:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}, "
            f"LANE={LANE}")
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(_panel_apply_cols_kernel, scale=scale, bm=bm,
                               bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d // bk, m // bm),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bm,), lambda k, t, idx: (t,)),          # v tile
        ],
        out_specs=pl.BlockSpec((bk,), lambda k, t, idx: (k,)),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),
            pltpu.VMEM((bm, bk, LANE), X.dtype),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((d,), acc),
        interpret=interpret,
    )(flat, X, v)

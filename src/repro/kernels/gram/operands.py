"""The PacketOperand layer: what the packet kernels gather, made first-class.

Formulations used to encode "how the packet's operand is sampled" by shaping
the array itself -- the dual pre-transposed each shard (``Xl.T``) so column
sampling became row sampling, doubling the resident dataset for the length of
the solve.  This module lifts the choice into an object owning the operand
array, its LAYOUT, and its GATHER STRATEGY:

* :class:`RowMajorOperand` -- array (S, C), samples are rows; the
  index-prefetched row-DMA kernels of ``sampled_kernel.py``.
* :class:`ColMajorOperand` -- array (C, S), samples are columns of the
  ORIGINAL layout; the lane-aligned column-tile kernels of
  ``sampled_colmajor.py``.  This is what lets ``_BoundDual`` bind X (d, n)
  with zero pre-transpose and zero extra resident copy.
* :class:`MaterializedOperand` -- array (S, S) of ALREADY-FORMED products
  (a kernel matrix K): the "Gram" is a gather, not a contraction.  This is
  the kernel-BDCD prerequisite (arXiv:2406.18001); smoke-proven through the
  same dispatch.

Uniform semantics in terms of the implicit sampled panel ``Y(flat)``,
shape (m, C):

    packet(flat, u):  G = scale * Y Y^T + reg*I,   r = scale_r * Y u
    apply(flat, v):   out(C) = scale * Y^T v
    matvec(flat, t):  out(m) = scale * Y t

(for ``MaterializedOperand`` the panel is the implicit factor with
``Y Y^T = K[flat][:, flat]`` and ``Y u = K[flat, :] u`` -- the kernel trick.)

Registration IS the protocol: a new operand kind implements these three
methods (plus ``dtype``/``layout``) and every consumer -- the engine's one
hot-loop body, ``ops.py``'s public entry points, the benchmarks -- dispatches
through it with zero edits.  ``as_operand`` keeps raw arrays working
everywhere (they mean row-major, the pre-PR-5 contract).

Knob resolution (``impl``/``bm``/``bk``) stays in ``ops.py``; the methods
here receive resolved knobs and own only padding + kernel selection.  Tile
defaults come from ``tuning.pick_tiles`` keyed on (shape, dtype, layout).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from . import ref, tuning
from .sampled_colmajor import (LANE, gram_packet_sampled_cols_pallas,
                               panel_apply_cols_pallas,
                               panel_matvec_cols_pallas)
from .sampled_kernel import (gram_packet_sampled_pallas, panel_apply_pallas,
                             panel_matvec_pallas)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def resolve_tiles(m: int, n: int, dtype, bm: int | None, bk: int | None,
                  layout: str = "rows") -> tuple[int, int]:
    """THE tile-clamp rule, shared by every consumer (ops.py's materialized
    entry points and both gather operands): explicit values win, else the
    tuning table's (m, n-contraction, dtype, layout) pick; both are clamped
    to the padded operand so they are directly usable as pallas block shapes.
    The contraction granule is the 128-lane width for row-major operands and
    the 8-row sublane for column-major ones (the contraction runs over X's
    rows there)."""
    k_granule = (tuning.LANE_GRANULE if layout == "rows"
                 else tuning.ROW_GRANULE)
    auto_bm, auto_bk = tuning.pick_tiles(m, n, dtype, layout=layout)
    bm_eff = min(bm, _round_up(m, tuning.ROW_GRANULE)) if bm else auto_bm
    bk_eff = min(bk, _round_up(n, k_granule)) if bk else auto_bk
    return bm_eff, bk_eff


@runtime_checkable
class PacketOperand(Protocol):
    """A packet operand: the array, its layout, and its gather strategy."""
    array: jax.Array
    layout: ClassVar[str]

    @property
    def dtype(self): ...
    @property
    def samples(self) -> int: ...
    @property
    def contraction(self) -> int: ...
    def packet(self, flat, u, *, scale, reg, scale_r, impl, bm, bk,
               symmetric_skip): ...
    def apply(self, flat, v, *, scale, impl, bm, bk): ...
    def matvec(self, flat, t, *, scale, impl, bm, bk): ...


@dataclasses.dataclass(frozen=True)
class RowMajorOperand:
    """Array (S, C); samples rows: Y = array[flat, :].  The PR-2 row-DMA
    gather kernels -- bm row copies of bk contiguous elements each."""
    array: jax.Array
    layout: ClassVar[str] = "rows"

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def samples(self) -> int:
        return self.array.shape[0]

    @property
    def contraction(self) -> int:
        return self.array.shape[1]

    def _tiles(self, m, bm, bk):
        return resolve_tiles(m, self.contraction, self.dtype, bm, bk, "rows")

    def packet(self, flat, u, *, scale, reg, scale_r, impl, bm, bk,
               symmetric_skip):
        if impl == "ref":
            return ref.gram_packet_sampled_ref(self.array, flat, u, scale,
                                               reg, scale_r)
        m = flat.shape[0]
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        # The operand's column pad is loop-invariant in the solvers' scans
        # (the array never changes across iterations), so XLA hoists it.
        Xp = _pad_axis(self.array, bk_eff, 1)
        up = _pad_axis(u, bk_eff, 0)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        G, r = gram_packet_sampled_pallas(
            Xp, flat_p, up, scale=scale, reg=reg, scale_r=scale_r, bm=bm_eff,
            bk=bk_eff, symmetric_skip=symmetric_skip,
            interpret=(impl == "pallas_interpret"))
        return G[:m, :m], r[:m]

    def apply(self, flat, v, *, scale, impl, bm, bk):
        if impl == "ref":
            return ref.panel_apply_ref(self.array, flat, v, scale)
        m = flat.shape[0]
        n = self.contraction
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        Xp = _pad_axis(self.array, bk_eff, 1)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        vp = _pad_axis(v, bm_eff, 0)
        out = panel_apply_pallas(Xp, flat_p, vp, scale=scale, bm=bm_eff,
                                 bk=bk_eff,
                                 interpret=(impl == "pallas_interpret"))
        return out[:n]

    def matvec(self, flat, t, *, scale, impl, bm, bk):
        if impl == "ref":
            return ref.panel_matvec_ref(self.array, flat, t, scale)
        m = flat.shape[0]
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        Xp = _pad_axis(self.array, bk_eff, 1)
        tp = _pad_axis(t, bk_eff, 0)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        out = panel_matvec_pallas(Xp, flat_p, tp, scale=scale, bm=bm_eff,
                                  bk=bk_eff,
                                  interpret=(impl == "pallas_interpret"))
        return out[:m]


@dataclasses.dataclass(frozen=True)
class ColMajorOperand:
    """Array (C, S); samples COLUMNS of the original layout:
    Y = array[:, flat].T.  The lane-aligned column-tile gather kernels --
    this is the dual's operand with no pre-transpose and no second copy."""
    array: jax.Array
    layout: ClassVar[str] = "cols"

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def samples(self) -> int:
        return self.array.shape[1]

    @property
    def contraction(self) -> int:
        return self.array.shape[0]

    def _tiles(self, m, bm, bk):
        return resolve_tiles(m, self.contraction, self.dtype, bm, bk, "cols")

    def _padded(self, bk_eff):
        # Pad d (contraction rows; zeros contribute nothing) to the bk tile
        # and n to the LANE width so every slab copy is in bounds.  Padded
        # index slots clamp to column 0 and only touch G/r rows >= m.
        return _pad_axis(_pad_axis(self.array, bk_eff, 0), LANE, 1)

    def packet(self, flat, u, *, scale, reg, scale_r, impl, bm, bk,
               symmetric_skip):
        if impl == "ref":
            return ref.gram_packet_sampled_cols_ref(self.array, flat, u,
                                                    scale, reg, scale_r)
        m = flat.shape[0]
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        Xp = self._padded(bk_eff)
        up = _pad_axis(u, bk_eff, 0)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        G, r = gram_packet_sampled_cols_pallas(
            Xp, flat_p, up, scale=scale, reg=reg, scale_r=scale_r, bm=bm_eff,
            bk=bk_eff, symmetric_skip=symmetric_skip,
            interpret=(impl == "pallas_interpret"))
        return G[:m, :m], r[:m]

    def apply(self, flat, v, *, scale, impl, bm, bk):
        if impl == "ref":
            return ref.panel_apply_cols_ref(self.array, flat, v, scale)
        m = flat.shape[0]
        d = self.contraction
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        Xp = self._padded(bk_eff)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        vp = _pad_axis(v, bm_eff, 0)
        out = panel_apply_cols_pallas(Xp, flat_p, vp, scale=scale, bm=bm_eff,
                                      bk=bk_eff,
                                      interpret=(impl == "pallas_interpret"))
        return out[:d]

    def matvec(self, flat, t, *, scale, impl, bm, bk):
        # out(m) = scale * array[:, flat]^T t.  The batched multi-tenant
        # engine's per-tenant dual residual: each route mirrors the fused
        # packet's r (same expression on ref, same accumulation cells in the
        # kernel) so batched residuals match single-solve residuals bitwise.
        if impl == "ref":
            return ref.panel_matvec_cols_ref(self.array, flat, t, scale)
        m = flat.shape[0]
        bm_eff, bk_eff = self._tiles(m, bm, bk)
        Xp = self._padded(bk_eff)
        tp = _pad_axis(t, bk_eff, 0)
        flat_p = _pad_axis(flat.astype(jnp.int32), bm_eff, 0)
        out = panel_matvec_cols_pallas(Xp, flat_p, tp, scale=scale,
                                       bm=bm_eff, bk=bk_eff,
                                       interpret=(impl == "pallas_interpret"))
        return out[:m]


@dataclasses.dataclass(frozen=True)
class MaterializedOperand:
    """Array K (S, S) of pre-materialized products (a kernel matrix): the
    packet's Gram is GATHERED, not contracted -- G = scale * K[flat][:, flat]
    + reg*I, r = scale_r * K[flat, :] u.  There is no panel to fuse away, so
    every impl runs the same jnp gather (validated like any other impl
    string; the kernel-BDCD formulation of arXiv:2406.18001 binds through
    here with zero engine edits)."""
    array: jax.Array
    layout: ClassVar[str] = "materialized"

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def samples(self) -> int:
        return self.array.shape[0]

    @property
    def contraction(self) -> int:
        return self.array.shape[1]

    def _acc(self):
        return jnp.float32 if self.dtype != jnp.float64 else jnp.float64

    def packet(self, flat, u, *, scale, reg, scale_r, impl, bm, bk,
               symmetric_skip):
        acc = self._acc()
        K = self.array
        rows = K[flat, :].astype(acc)
        G = scale * rows[:, flat] + reg * jnp.eye(flat.shape[0], dtype=acc)
        sr = scale if scale_r is None else scale_r
        r = sr * (rows @ u.astype(acc))
        return G, r

    def apply(self, flat, v, *, scale, impl, bm, bk):
        acc = self._acc()
        out = scale * jnp.einsum("mk,m->k", self.array[flat, :], v,
                                 preferred_element_type=acc)
        return out.astype(acc)

    def matvec(self, flat, t, *, scale, impl, bm, bk):
        acc = self._acc()
        out = scale * jnp.einsum("mk,k->m", self.array[flat, :], t,
                                 preferred_element_type=acc)
        return out.astype(acc)


def as_operand(x) -> PacketOperand:
    """Normalize: PacketOperands pass through; raw arrays mean row-major
    (the pre-PR-5 contract every existing caller relies on)."""
    if isinstance(x, (RowMajorOperand, ColMajorOperand, MaterializedOperand)):
        return x
    if isinstance(x, PacketOperand):      # duck-typed third-party operand
        return x
    return RowMajorOperand(x)

from .ops import (PacketPlan, gram, gram_packet, gram_packet_sampled,
                  normal_matvec, panel_apply, panel_matvec)
from .ref import (gram_packet_ref, gram_packet_sampled_ref, gram_ref,
                  panel_apply_ref, panel_matvec_ref)
from . import tuning

__all__ = [
    "PacketPlan", "gram", "gram_packet", "gram_packet_sampled", "panel_apply",
    "panel_matvec", "normal_matvec", "gram_ref", "gram_packet_ref",
    "gram_packet_sampled_ref", "panel_apply_ref", "panel_matvec_ref",
    "tuning",
]

from .ops import gram, gram_packet
from .ref import gram_packet_ref, gram_ref

__all__ = ["gram", "gram_packet", "gram_ref", "gram_packet_ref"]

from .operands import (ColMajorOperand, MaterializedOperand, PacketOperand,
                       RowMajorOperand, as_operand)
from .ops import (PacketPlan, gram, gram_packet, gram_packet_sampled,
                  normal_matvec, panel_apply, panel_matvec)
from .ref import (gram_packet_ref, gram_packet_sampled_cols_ref,
                  gram_packet_sampled_ref, gram_ref, panel_apply_cols_ref,
                  panel_apply_ref, panel_matvec_cols_ref, panel_matvec_ref)
from . import tuning

__all__ = [
    "PacketPlan", "PacketOperand", "RowMajorOperand", "ColMajorOperand",
    "MaterializedOperand", "as_operand",
    "gram", "gram_packet", "gram_packet_sampled", "panel_apply",
    "panel_matvec", "normal_matvec", "gram_ref", "gram_packet_ref",
    "gram_packet_sampled_ref", "gram_packet_sampled_cols_ref",
    "panel_apply_ref", "panel_apply_cols_ref", "panel_matvec_ref",
    "panel_matvec_cols_ref", "tuning",
]

"""Index-prefetched Pallas kernels for the panel-free sampled-Gram hot path.

PR 1 wired the solvers' Gram + residual pairs through ``gram_packet``, but the
solvers still materialized the sampled panel ``Y = X[flat, :]`` in HBM before
the kernel ran.  That panel crosses HBM three times per outer iteration --
gather write, Gram read, and the deferred ``alpha += Y^T dws`` read -- even
though the sb x sb Gram is the only compute that matters.  The kernels here
erase the panel entirely:

* ``gram_packet_sampled_pallas``: the sb block indices are *scalar-prefetched*
  into SMEM (``pltpu.PrefetchScalarGridSpec``), X stays un-blocked in HBM
  (``TPUMemorySpace.ANY``), and each grid cell DMA-gathers exactly the bm
  sampled rows x bk contraction columns it needs into VMEM scratch before
  feeding the MXU.  Same fused output as ``gram_packet_pallas``:
  ``(G = scale*Y Y^T + reg*I, r = scale_r*Y u)``.
* ``panel_apply_pallas``: the deferred vector updates (``alpha += Y^T dws`` /
  ``wl -= Yl @ das``) computed straight from X + indices -- the transpose-side
  companion, ``out(n) = scale * X[flat, :]^T v``.
* ``panel_matvec_pallas``: the row-side companion ``out(m) = scale *
  X[flat, :] t`` (with ``flat = arange`` this is a streaming matvec; the CG
  normal-equations route in ``core/krylov.py`` uses it through the dispatch
  layer).

HBM traffic per outer iteration (words, panel of sb x n, B = m/bm row
blocks; both Gram kernels stream their operand tiles once per grid cell, so
the B-fold Gram read is common to both schedules):
  materialized baseline: read X rows (gather) + write panel + B x read
  panel (Gram) + read panel (apply)      ~= (B + 3) sb n
  panel-free (these kernels): B x read X rows (Gram) + read X rows (apply)
                                          ~= (B + 1) sb n
i.e. the gather write and two of the three panel re-reads vanish -- a ~2x
traffic cut at the solvers' operating points, where sb <= bm keeps B = 1
(`repro.core.cost_model.packet_hbm_bytes` carries the model; the ratio is
recorded in the bench smoke baseline).

Per-cell DMA shape: bm row copies of bk elements each, issued back-to-back on
a per-row semaphore array and then drained, so the gather overlaps its own
issue latency.  At the default (bm=128, bk=512, f32) tiles each copy is 2 KiB
-- large enough to amortize DMA setup on TPU v5e -- and VMEM holds
2*(128*512)*4B of gathered panels + the 128x128 G tile ~= 2.6 MiB.

Grid layout matches ``gram_kernel``: grid = (m/bm, m/bm, n/bk) with k
innermost so each (i, j) G tile stays resident across the contraction;
symmetric skip zero-fills j > i cells and the wrapper mirrors the lower
triangle.  Requires m % bm == 0 and n % bk == 0 (ops.py pads; padded index
slots point at row 0 and their G/r rows are sliced off, padded k columns are
zero so they contribute nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gram_kernel import _add_diag_reg, mirror_lower


def _gather_rows(idx_ref, x_ref, dst, sems, base, k, bm: int, bk: int):
    """DMA rows ``X[idx_ref[base + r], k*bk : (k+1)*bk] -> dst[r]`` for
    r < bm: start all copies on per-row semaphores, then drain them, so the
    row DMAs are in flight concurrently."""

    def _copy(r):
        row = idx_ref[base + r]
        return pltpu.make_async_copy(
            x_ref.at[row, pl.ds(k * bk, bk)], dst.at[r], sems.at[r])

    def _start(r, _):
        _copy(r).start()
        return 0

    def _wait(r, _):
        _copy(r).wait()
        return 0

    jax.lax.fori_loop(0, bm, _start, 0)
    jax.lax.fori_loop(0, bm, _wait, 0)


def _sampled_packet_kernel(idx_ref, x_ref, u_ref, g_ref, r_ref, yi, yj,
                           sem_i, sem_j, *, scale: float, reg: float,
                           scale_r: float, n_k: int, bm: int, bk: int,
                           symmetric_skip: bool):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    acc = g_ref.dtype

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(jnp.logical_and(k == 0, j == 0))
    def _init_r():
        r_ref[...] = jnp.zeros_like(r_ref)

    compute = jnp.logical_or(j <= i, jnp.logical_not(symmetric_skip))

    # Gather the row panel (always needed when computing: j == 0 residual
    # cells satisfy j <= i) and the column panel (only off-diagonal cells;
    # the diagonal reuses the row gather).
    @pl.when(compute)
    def _gather_i():
        _gather_rows(idx_ref, x_ref, yi, sem_i, i * bm, k, bm, bk)

    @pl.when(jnp.logical_and(compute, i != j))
    def _gather_j():
        _gather_rows(idx_ref, x_ref, yj, sem_j, j * bm, k, bm, bk)

    @pl.when(compute)
    def _accumulate():
        a_i = yi[...]
        a_j = jnp.where(i == j, yi[...], yj[...])
        g_ref[...] += scale * jax.lax.dot_general(
            a_i, a_j, (((1,), (1,)), ((), ())),
            preferred_element_type=acc)

    # Residual r = scale_r * Y u rides along on the j == 0 cells (computed
    # exactly once per (i, k)).
    @pl.when(j == 0)
    def _residual():
        u = u_ref[...]
        r_ref[...] += scale_r * jax.lax.dot_general(
            yi[...], u[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=acc)[:, 0]

    @pl.when(jnp.logical_and(k == n_k - 1, i == j))
    def _reg():
        _add_diag_reg(g_ref, reg)


@functools.partial(jax.jit, static_argnames=("scale", "reg", "scale_r", "bm",
                                             "bk", "symmetric_skip",
                                             "interpret"))
def gram_packet_sampled_pallas(X: jax.Array, flat: jax.Array, u: jax.Array, *,
                               scale: float = 1.0, reg: float = 0.0,
                               scale_r: float | None = None, bm: int = 128,
                               bk: int = 512, symmetric_skip: bool = True,
                               interpret: bool = False
                               ) -> tuple[jax.Array, jax.Array]:
    """(G, r) = (scale*Y Y^T + reg*I, scale_r*Y u) for Y = X[flat, :], without
    materializing Y.  X (d, n) with n % bk == 0, flat (m,) int32 with
    m % bm == 0, u (n,).  Accumulates f32, or f64 for f64 input (the solver
    exactness path runs in interpret mode on CPU)."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or n % bk:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}")
    n_k = n // bk
    grid = (m // bm, m // bm, n_k)
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(
        _sampled_packet_kernel, scale=scale, reg=reg,
        scale_r=(scale if scale_r is None else scale_r), n_k=n_k, bm=bm,
        bk=bk, symmetric_skip=symmetric_skip)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # flat -> SMEM, pre-grid
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bk,), lambda i, j, k, idx: (k,)),       # u tile
        ],
        out_specs=[
            pl.BlockSpec((bm, bm), lambda i, j, k, idx: (i, j)),  # G tile
            pl.BlockSpec((bm,), lambda i, j, k, idx: (i,)),       # r tile
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),           # gathered row panel
            pltpu.VMEM((bm, bk), X.dtype),           # gathered col panel
            pltpu.SemaphoreType.DMA((bm,)),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    g, r = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, m), acc),
            jax.ShapeDtypeStruct((m,), acc),
        ],
        interpret=interpret,
    )(flat, X, u)

    if symmetric_skip:
        g = mirror_lower(g, bm)
    return g, r


def _panel_apply_kernel(idx_ref, x_ref, v_ref, o_ref, ybuf, sems, *,
                        scale: float, bm: int, bk: int):
    k, t = pl.program_id(0), pl.program_id(1)
    acc = o_ref.dtype

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _gather_rows(idx_ref, x_ref, ybuf, sems, t * bm, k, bm, bk)
    o_ref[...] += scale * jax.lax.dot_general(
        ybuf[...], v_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "interpret"))
def panel_apply_pallas(X: jax.Array, flat: jax.Array, v: jax.Array, *,
                       scale: float = 1.0, bm: int = 128, bk: int = 512,
                       interpret: bool = False) -> jax.Array:
    """out(n) = scale * X[flat, :]^T v without materializing the panel: the
    deferred ``alpha += Y^T dws`` / ``wl -= Yl das`` updates.  Grid (n/bk,
    m/bm) with the row tiles innermost so each output tile accumulates in
    VMEM; padded index slots must carry v == 0 (ops.py guarantees this)."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or n % bk:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}")
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(_panel_apply_kernel, scale=scale, bm=bm, bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // bk, m // bm),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bm,), lambda k, t, idx: (t,)),          # v tile
        ],
        out_specs=pl.BlockSpec((bk,), lambda k, t, idx: (k,)),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), acc),
        interpret=interpret,
    )(flat, X, v)


def _panel_matvec_kernel(idx_ref, x_ref, t_ref, o_ref, ybuf, sems, *,
                         scale: float, bm: int, bk: int):
    i, k = pl.program_id(0), pl.program_id(1)
    acc = o_ref.dtype

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _gather_rows(idx_ref, x_ref, ybuf, sems, i * bm, k, bm, bk)
    o_ref[...] += scale * jax.lax.dot_general(
        ybuf[...], t_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bk", "interpret"))
def panel_matvec_pallas(X: jax.Array, flat: jax.Array, t: jax.Array, *,
                        scale: float = 1.0, bm: int = 128, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """out(m) = scale * X[flat, :] t without materializing the panel (the
    residual direction; with flat = arange(d) a streaming X @ t)."""
    d, n = X.shape
    m = flat.shape[0]
    if m % bm or n % bk:
        raise ValueError(
            f"flat ({m},) / X {X.shape} not tiled by bm={bm}, bk={bk}")
    acc = jnp.float64 if X.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(_panel_matvec_kernel, scale=scale, bm=bm, bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // bm, n // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # X in HBM
            pl.BlockSpec((bk,), lambda i, k, idx: (k,)),          # t tile
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, k, idx: (i,)),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), X.dtype),
            pltpu.SemaphoreType.DMA((bm,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), acc),
        interpret=interpret,
    )(flat, X, t)

"""Pallas TPU kernel for the CA-BCD/CA-BDCD Gram packet.

This is the paper's compute hot spot: the s-step transformation converts s
BLAS-1/2 iterations into one BLAS-3 ``sb x sb`` Gram product (section 1: the
same insight that drives s-step Krylov methods), so the kernel below is where
the MXU earns the extra ``s x`` flops the method trades for latency.

TPU mapping (DESIGN.md section 2.3):
  * grid = (m/bm, m/bm, n/bk); k innermost so each (i, j) output tile stays
    resident in VMEM across the full contraction.
  * BlockSpecs tile A twice -- as the row panel (i, k) and the column panel
    (j, k) -- with 128-aligned tiles feeding the 128x128 MXU; accumulation in
    f32 regardless of input dtype.
  * symmetry: G = G^T, so blocks with j > i are skipped (zero-filled) and the
    wrapper mirrors the strict lower triangle -- a ~2x MXU saving measured in
    the section Perf log.
  * the residual vector r = scale * A @ u rides along in the same pass
    (computed by the j == i grid cells against the u tile), so the packet
    needs ONE read of A from HBM instead of two.  ``gram_pallas`` runs the
    same body with the residual refs statically absent, for Gram-only callers
    (``ops.gram``) -- no zeros-u is ever computed or written.

VMEM budget at the default tiles (bm=128, bk=512, f32):
  2 * (128*512) * 4B (A panels) + 128*128*4B (G tile) + 512*4B (u) ~= 2.6 MiB
well inside the ~16 MiB/core VMEM of TPU v5e.

The index-prefetched sampled variant (no materialized operand panel) lives in
``sampled_kernel.py`` and shares ``_add_diag_reg`` / ``mirror_lower``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128   # Gram tile edge (MXU-aligned)
DEFAULT_BK = 512   # contraction tile


def _add_diag_reg(g_ref, reg: float):
    """Add reg*I to the (bm, bm) tile in g_ref (true-diagonal tiles only)."""
    bm = g_ref.shape[0]
    acc = g_ref.dtype
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    g_ref[...] += jnp.where(rows == cols, jnp.asarray(reg, acc),
                            jnp.asarray(0.0, acc))


def mirror_lower(g: jax.Array, bm: int) -> jax.Array:
    """Fill the skipped blocks strictly above the block diagonal from the
    transpose (diagonal blocks were computed fully)."""
    blk = jnp.arange(g.shape[0]) // bm
    upper = blk[:, None] < blk[None, :]
    return jnp.where(upper, g.T, g)


def _gram_packet_kernel(a_i_ref, a_j_ref, u_ref, g_ref, r_ref, *,
                        scale: float, reg: float, scale_r: float, n_k: int,
                        symmetric_skip: bool):
    """Shared body: ``u_ref``/``r_ref`` are None for the Gram-only variant
    (a static, trace-time choice -- the residual ops simply don't exist)."""
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    acc = g_ref.dtype

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    if r_ref is not None:
        @pl.when(jnp.logical_and(k == 0, j == 0))
        def _init_r():
            r_ref[...] = jnp.zeros_like(r_ref)

    compute = jnp.logical_or(j <= i, jnp.logical_not(symmetric_skip))

    @pl.when(compute)
    def _accumulate():
        a_i = a_i_ref[...]
        a_j = a_j_ref[...]
        g_ref[...] += scale * jax.lax.dot_general(
            a_i, a_j, (((1,), (1,)), ((), ())),
            preferred_element_type=acc)

    # Residual panel: each row block i accumulates A_i @ u once per k tile;
    # attach it to the j == 0 cells so it is computed exactly once.
    if r_ref is not None:
        @pl.when(j == 0)
        def _residual():
            a_i = a_i_ref[...]
            u = u_ref[...]
            r_ref[...] += scale_r * jax.lax.dot_general(
                a_i, u[:, None], (((1,), (0,)), ((), ())),
                preferred_element_type=acc)[:, 0]

    # Regularizer on the true diagonal, once, on the last k step.
    @pl.when(jnp.logical_and(k == n_k - 1, i == j))
    def _reg():
        _add_diag_reg(g_ref, reg)


def _gram_only_kernel(a_i_ref, a_j_ref, g_ref, **kw):
    _gram_packet_kernel(a_i_ref, a_j_ref, None, g_ref, None, **kw)


@functools.partial(jax.jit, static_argnames=("scale", "reg", "scale_r", "bm",
                                             "bk", "symmetric_skip",
                                             "interpret"))
def gram_packet_pallas(A: jax.Array, u: jax.Array, *, scale: float = 1.0,
                       reg: float = 0.0, scale_r: float | None = None,
                       bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                       symmetric_skip: bool = True,
                       interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(G, r) = (scale*A@A^T + reg*I, scale_r*A@u) for A (m, n), u (n,).

    Requires m % bm == 0 and n % bk == 0 (ops.py pads).  Accumulates and
    returns f32, or f64 when the input is f64 (the x64 solver-exactness path
    runs this kernel in interpret mode on CPU).
    """
    m, n = A.shape
    if m % bm or n % bk:
        raise ValueError(f"A shape {A.shape} not tiled by bm={bm}, bk={bk}")
    n_k = n // bk
    grid = (m // bm, m // bm, n_k)
    acc = jnp.float64 if A.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(
        _gram_packet_kernel, scale=scale, reg=reg,
        scale_r=(scale if scale_r is None else scale_r), n_k=n_k,
        symmetric_skip=symmetric_skip)

    g, r = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # A row panel
            pl.BlockSpec((bm, bk), lambda i, j, k: (j, k)),   # A col panel
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),        # u tile
        ],
        out_specs=[
            pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),   # G tile
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),        # r tile
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, m), acc),
            jax.ShapeDtypeStruct((m,), acc),
        ],
        interpret=interpret,
    )(A, A, u)  # A appears twice: once as the row panel, once as the column panel

    if symmetric_skip:
        g = mirror_lower(g, bm)
    return g, r


@functools.partial(jax.jit, static_argnames=("scale", "reg", "bm", "bk",
                                             "symmetric_skip", "interpret"))
def gram_pallas(A: jax.Array, *, scale: float = 1.0, reg: float = 0.0,
                bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                symmetric_skip: bool = True,
                interpret: bool = False) -> jax.Array:
    """G = scale*A@A^T + reg*I for A (m, n): the packet body with the
    residual refs statically absent (ops.gram dispatches here instead of
    zero-feeding the packet)."""
    m, n = A.shape
    if m % bm or n % bk:
        raise ValueError(f"A shape {A.shape} not tiled by bm={bm}, bk={bk}")
    n_k = n // bk
    acc = jnp.float64 if A.dtype == jnp.float64 else jnp.float32

    kernel = functools.partial(_gram_only_kernel, scale=scale, reg=reg,
                               scale_r=1.0, n_k=n_k,
                               symmetric_skip=symmetric_skip)
    g = pl.pallas_call(
        kernel,
        grid=(m // bm, m // bm, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # A row panel
            pl.BlockSpec((bm, bk), lambda i, j, k: (j, k)),   # A col panel
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), acc),
        interpret=interpret,
    )(A, A)

    if symmetric_skip:
        g = mirror_lower(g, bm)
    return g

"""Tile-size selection for the Gram kernels: (bm, bk) per (sb, n, dtype,
layout).

The static 128/512 defaults (PR 1) leave MXU utilization on the table at the
solver's actual operating points -- small sb (s*b in the tens) against a wide
contraction, or narrow local shards in the distributed layouts.  This module
replaces them with a lookup table keyed on bucketed ``(sb, n, dtype,
layout)``:

* ``pick_tiles(m, n, dtype, layout="rows")`` -- the single entry point the
  operand layer consults whenever a caller does not pin ``bm``/``bk``
  explicitly.  ``n`` is the CONTRACTION length (the operand's columns for the
  row-sampled layout; X's rows d for the column-sampled layout).  Exact-bucket
  hits come from ``_TABLE``; misses fall back to the per-layout heuristic
  (rows: cap at 128/512; cols: cap at the smaller 8/256 tiles the slab
  scratch affords), so behaviour without a table entry is unchanged.
* ``benchmarks/gram_autotune.py`` sweeps the candidate grid for BOTH layouts
  on the running backend and emits a JSON table; ``load_table(path)`` /
  ``register_table(mapping)`` merge it into the live table (also honoured at
  import time via the ``REPRO_GRAM_TUNING`` env var so TPU runs can ship
  their sweep results without code changes).  Old three-field keys
  (``"m,n,dtype"``) load unchanged and mean row-major.

Buckets are powers of two: a shape belongs to the smallest power-of-two
bucket >= its padded size.  That keeps the table small while distinguishing
the regimes that matter (VMEM pressure scales with bm*bk -- LANE-amplified
for the column gather's slabs -- and MXU efficiency with how close bm is
to 128).
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp

from .gram_kernel import DEFAULT_BK, DEFAULT_BM
from .sampled_colmajor import DEFAULT_BK_COLS, DEFAULT_BM_COLS

# Hardware granules: 8-row sublanes, 128-element lanes (f32; the kernel pads
# bf16 the same way and lets Mosaic repack).
ROW_GRANULE = 8
LANE_GRANULE = 128

LAYOUTS = ("rows", "cols")

# Candidate grids swept by benchmarks/gram_autotune.py.  The column-gather
# kernel's slab scratch is LANE x the panel, so its candidates stay small.
BM_CANDIDATES = (8, 16, 32, 64, 128)
BK_CANDIDATES = (128, 256, 512, 1024)
BM_CANDIDATES_COLS = (8, 16, 32)
BK_CANDIDATES_COLS = (64, 128, 256, 512)

_DEFAULTS = {"rows": (DEFAULT_BM, DEFAULT_BK),
             "cols": (DEFAULT_BM_COLS, DEFAULT_BK_COLS)}

# Seed table from the CPU-container sweep (make bench-smoke runs the ref
# backend, so these entries encode shape-bucketing only, not TPU timings; a
# real-TPU sweep overwrites them via REPRO_GRAM_TUNING).  Keys are
# (m_bucket, n_bucket, dtype_name, layout).
_TABLE: dict[tuple[int, int, str, str], tuple[int, int]] = {
    # solver operating points: sb = s*b in the tens, n in the thousands
    (32, 1024, "float32", "rows"): (32, 512),
    (32, 4096, "float32", "rows"): (32, 1024),
    (64, 4096, "float32", "rows"): (64, 512),
    (128, 4096, "float32", "rows"): (128, 512),
    (128, 32768, "float32", "rows"): (128, 1024),
    (256, 32768, "float32", "rows"): (128, 1024),
    (128, 32768, "bfloat16", "rows"): (128, 1024),
    # dual operating points: sb' in the tens against a d-length contraction
    (32, 512, "float32", "cols"): (8, 256),
    (64, 4096, "float32", "cols"): (16, 512),
}


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _bucket(x: int) -> int:
    b = 1
    while b < x:
        b *= 2
    return b


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def _check_layout(layout: str) -> None:
    if layout not in LAYOUTS:
        raise ValueError(
            f"unknown operand layout {layout!r}; expected one of {LAYOUTS}")


def pick_tiles(m: int, n: int, dtype, layout: str = "rows"
               ) -> tuple[int, int]:
    """(bm, bk) for an (m, n-contraction) Gram operand in ``layout``: table
    hit, else the layout's heuristic default.

    The returned tiles never exceed the padded operand, so callers can use
    them directly as pallas block shapes after the operand layer's
    pad-to-tile.  For ``layout="cols"`` the contraction axis pads on the
    8-row sublane granule (it runs over X's rows), not the 128 lane granule.
    """
    _check_layout(layout)
    k_granule = LANE_GRANULE if layout == "rows" else ROW_GRANULE
    m_pad = _round_up(max(m, 1), ROW_GRANULE)
    n_pad = _round_up(max(n, 1), k_granule)
    key = (_bucket(m_pad), _bucket(n_pad), _dtype_name(dtype), layout)
    bm, bk = _TABLE.get(key, _DEFAULTS[layout])
    return min(bm, m_pad), min(bk, n_pad)


def register_table(mapping: dict) -> None:
    """Merge entries into the live table.  Keys may be tuples or the JSON
    string forms ``"m_bucket,n_bucket,dtype"`` (legacy, meaning row-major)
    and ``"m_bucket,n_bucket,dtype,layout"``; values are (bm, bk)."""
    for k, v in mapping.items():
        if isinstance(k, str):
            parts = k.split(",")
            if len(parts) == 3:            # pre-PR-5 table: row-major
                parts.append("rows")
            mb, nb, dt, layout = parts
            k = (int(mb), int(nb), dt, layout)
        elif len(k) == 3:
            k = (*k, "rows")
        _check_layout(k[3])
        _TABLE[tuple(k)] = (int(v[0]), int(v[1]))


def load_table(path: str) -> int:
    """Load a gram_autotune.py JSON table; returns #entries merged."""
    with open(path) as f:
        data = json.load(f)
    table = data.get("table", data)
    register_table(table)
    return len(table)


def table_snapshot() -> dict[str, tuple[int, int]]:
    """JSON-serializable copy of the live table (for gram_autotune output)."""
    return {f"{k[0]},{k[1]},{k[2]},{k[3]}": v for k, v in sorted(_TABLE.items())}


def table_entries() -> list[tuple[tuple[int, int, str, str], tuple[int, int]]]:
    """Sorted (key, (bm, bk)) pairs of the LIVE table -- built-ins plus
    anything merged via register_table/REPRO_GRAM_TUNING.  The static plan
    pass (``repro.analysis.plan_pass``) sweeps these against the VMEM budget
    and alignment granules, so a bad autotune table fails in CI instead of
    inside a Mosaic compile."""
    return sorted(_TABLE.items())


_env_table = os.environ.get("REPRO_GRAM_TUNING")
if _env_table:
    # Setting the env var is an explicit opt-in: a bad path must fail loudly,
    # not silently fall back to the built-in table.
    if not os.path.exists(_env_table):
        raise FileNotFoundError(
            f"REPRO_GRAM_TUNING={_env_table!r} does not exist; run "
            "benchmarks/gram_autotune.py to generate it or unset the var")
    load_table(_env_table)

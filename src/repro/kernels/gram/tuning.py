"""Tile-size selection for the Gram kernels: (bm, bk) per (sb, n, dtype).

The static 128/512 defaults (PR 1) leave MXU utilization on the table at the
solver's actual operating points -- small sb (s*b in the tens) against a wide
contraction, or narrow local shards in the distributed layouts.  This module
replaces them with a lookup table keyed on bucketed ``(sb, n, dtype)``:

* ``pick_tiles(m, n, dtype)`` -- the single entry point ``ops.py`` consults
  whenever a caller does not pin ``bm``/``bk`` explicitly.  Exact-bucket hits
  come from ``_TABLE``; misses fall back to the PR-1 heuristic (cap at 128/512,
  round up to the 8-row sublane / 128-lane granules), so behaviour without a
  table entry is unchanged.
* ``benchmarks/gram_autotune.py`` sweeps the candidate grid on the running
  backend and emits a JSON table; ``load_table(path)`` /
  ``register_table(mapping)`` merge it into the live table (also honoured at
  import time via the ``REPRO_GRAM_TUNING`` env var so TPU runs can ship their
  sweep results without code changes).

Buckets are powers of two: a shape belongs to the smallest power-of-two
bucket >= its padded size.  That keeps the table small while distinguishing
the regimes that matter (VMEM pressure scales with bm*bk; MXU efficiency with
how close bm is to 128).
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp

from .gram_kernel import DEFAULT_BK, DEFAULT_BM

# Hardware granules: 8-row sublanes, 128-element lanes (f32; the kernel pads
# bf16 the same way and lets Mosaic repack).
ROW_GRANULE = 8
LANE_GRANULE = 128

# Candidate grid swept by benchmarks/gram_autotune.py.
BM_CANDIDATES = (8, 16, 32, 64, 128)
BK_CANDIDATES = (128, 256, 512, 1024)

# Seed table from the CPU-container sweep (make bench-smoke runs the ref
# backend, so these entries encode shape-bucketing only, not TPU timings; a
# real-TPU sweep overwrites them via REPRO_GRAM_TUNING).  Keys are
# (m_bucket, n_bucket, dtype_name).
_TABLE: dict[tuple[int, int, str], tuple[int, int]] = {
    # solver operating points: sb = s*b in the tens, n in the thousands
    (32, 1024, "float32"): (32, 512),
    (32, 4096, "float32"): (32, 1024),
    (64, 4096, "float32"): (64, 512),
    (128, 4096, "float32"): (128, 512),
    (128, 32768, "float32"): (128, 1024),
    (256, 32768, "float32"): (128, 1024),
    (128, 32768, "bfloat16"): (128, 1024),
}


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _bucket(x: int) -> int:
    b = 1
    while b < x:
        b *= 2
    return b


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def pick_tiles(m: int, n: int, dtype) -> tuple[int, int]:
    """(bm, bk) for an (m, n) Gram operand: table hit, else PR-1 heuristic.

    The returned tiles never exceed the padded operand, so callers can use
    them directly as pallas block shapes after ops.py's pad-to-tile.
    """
    m_pad = _round_up(max(m, 1), ROW_GRANULE)
    n_pad = _round_up(max(n, 1), LANE_GRANULE)
    key = (_bucket(m_pad), _bucket(n_pad), _dtype_name(dtype))
    bm, bk = _TABLE.get(key, (DEFAULT_BM, DEFAULT_BK))
    return min(bm, m_pad), min(bk, n_pad)


def register_table(mapping: dict) -> None:
    """Merge entries into the live table.  Keys may be tuples or the JSON
    string form ``"m_bucket,n_bucket,dtype"``; values are (bm, bk)."""
    for k, v in mapping.items():
        if isinstance(k, str):
            mb, nb, dt = k.split(",")
            k = (int(mb), int(nb), dt)
        _TABLE[tuple(k)] = (int(v[0]), int(v[1]))


def load_table(path: str) -> int:
    """Load a gram_autotune.py JSON table; returns #entries merged."""
    with open(path) as f:
        data = json.load(f)
    table = data.get("table", data)
    register_table(table)
    return len(table)


def table_snapshot() -> dict[str, tuple[int, int]]:
    """JSON-serializable copy of the live table (for gram_autotune output)."""
    return {f"{k[0]},{k[1]},{k[2]}": v for k, v in sorted(_TABLE.items())}


_env_table = os.environ.get("REPRO_GRAM_TUNING")
if _env_table:
    # Setting the env var is an explicit opt-in: a bad path must fail loudly,
    # not silently fall back to the built-in table.
    if not os.path.exists(_env_table):
        raise FileNotFoundError(
            f"REPRO_GRAM_TUNING={_env_table!r} does not exist; run "
            "benchmarks/gram_autotune.py to generate it or unset the var")
    load_table(_env_table)

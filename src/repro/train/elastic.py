"""Elastic scaling: restart the same logical job on a different device count.

Checkpoints store logical (unsharded) arrays, so elasticity is a placement
problem: build the mesh for the new world size, re-derive shardings from the
same rule table, and device_put.  plan_mesh keeps the TP degree at most the
requested width and folds everything else into (pod x data); divisibility
guards in the rule table absorb shapes that stop dividing after a resize.

Straggler/failure story at 1000+ nodes (DESIGN.md section 3): a failed pod
drops out, the job restarts from the newest valid checkpoint (CRC-verified,
next-older fallback) on the surviving world size, and the data stream resumes
exactly (counter-based Philox keyed by step).  The s-step solver layer reduces
sync frequency by s, which directly shrinks the window in which a straggler
can stall the collective.
"""
from __future__ import annotations

import jax

from repro import compat
from .trainer import train_step_shardings


def plan_mesh(n_devices: int, tp: int = 16, pods: int | None = None):
    """Choose (pod, data, model) for a world size.  TP degree never exceeds
    the device count; the data axis absorbs the remainder."""
    tp = min(tp, n_devices)
    while n_devices % tp:
        tp //= 2
    rest = n_devices // tp
    if pods and rest % pods == 0 and pods > 1:
        return compat.make_mesh((pods, rest // pods, tp),
                                ("pod", "data", "model"))
    return compat.make_mesh((rest, tp), ("data", "model"))


def plan_solver_mesh(n_devices: int, name: str = "shards"):
    """The solver-layer counterpart of :func:`plan_mesh`: a 1D mesh over the
    surviving world size, capped at the devices actually present.  The s-step
    engine's ``Formulation.pad_shards`` re-pads the logical operands to any
    shard count, so an elastic restart after device loss is just this mesh
    plus a warm-start from the newest checkpoint (``faults.solve_supervised``)."""
    n = max(1, min(n_devices, len(jax.devices())))
    return compat.make_mesh((n,), (name,))


def reshard_state(state, model_cfg, new_mesh):
    """Place a (host or differently-sharded) train state onto new_mesh."""
    sh, _ = train_step_shardings(model_cfg, new_mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh)

"""Training loop substrate: sharded train_step factory + the Trainer driver.

The train_step is one jit'd program: grad accumulation over microbatches via
lax.scan (f32 accumulators), bf16 gradient flow (the DP all-reduce moves bf16
-- 2x fewer wire bytes than f32, a distributed-optimization trick recorded in
the roofline table), AdamW with ZeRO-sharded f32 master/moments, donated state.

The same factory serves the real CPU training examples (examples/train_lm.py)
and the 512-device dry-run lowering (launch/dryrun.py) -- the dry-run compiles
exactly the program a pod job would run.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.models import api
from repro.models.module import ParamSpec, init_params
from repro.models.sharding import make_rules
from repro.optim import AdamWConfig, adamw_update, init_opt_state, opt_state_specs
from repro.optim.schedules import cosine_warmup

TrainState = dict  # {"params", "opt": {"master","m","v"}, "step"}


# ---------------------------------------------------------------- specs ----

def train_state_specs(model_cfg) -> dict:
    pspecs = api.param_specs(model_cfg)
    return {
        "params": pspecs,
        "opt": opt_state_specs(pspecs),
        "step": ParamSpec((), (), jnp.int32, init="zeros"),
    }


def train_step_shardings(model_cfg, mesh, shape_cfg=None):
    """(state shardings, batch shardings) for jit in_shardings."""
    param_rules = make_rules(mesh, fsdp=model_cfg.fsdp)
    zero_rules = make_rules(mesh, fsdp=True)  # ZeRO-1: always shard opt state

    specs = train_state_specs(model_cfg)
    state_sh = {
        "params": jax.tree.map(param_rules.sharding_for, specs["params"],
                               is_leaf=lambda x: isinstance(x, ParamSpec)),
        "opt": jax.tree.map(zero_rules.sharding_for, specs["opt"],
                            is_leaf=lambda x: isinstance(x, ParamSpec)),
        "step": NamedSharding(mesh, P()),
    }
    batch_spec = param_rules.spec_for((1 << 30, 1), ("batch", "seq"))
    bsh = NamedSharding(mesh, batch_spec)
    batch_sh = {"tokens": bsh, "labels": bsh, "mask": bsh}
    if model_cfg.family == "vlm":
        batch_sh["extra_embeds"] = NamedSharding(
            mesh, param_rules.spec_for((1 << 30, 1, 1), ("batch", "seq", "embed")))
    if model_cfg.family == "audio":
        batch_sh["src_embeds"] = NamedSharding(
            mesh, param_rules.spec_for((1 << 30, 1, 1), ("batch", "seq", "embed")))
    return state_sh, batch_sh


def abstract_train_state(model_cfg, mesh) -> dict:
    """ShapeDtypeStruct state tree with shardings (dry-run / restore target)."""
    specs = train_state_specs(model_cfg)
    sh, _ = train_step_shardings(model_cfg, mesh)

    def mk(spec, sharding):
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sharding)

    return jax.tree.map(mk, specs, sh,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ----------------------------------------------------------- train step ----

def make_train_step(model_cfg, opt_cfg: AdamWConfig, microbatches: int = 1):
    def loss_for(p, mb):
        return api.loss_fn(p, model_cfg, mb)

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gacc, lacc = carry
                (l, m), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    gacc, g)
                return (gacc, lacc + l / microbatches), m

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                acc_step, (gacc0, jnp.float32(0)), mbs)
            metrics = jax.tree.map(lambda x: x[-1], metrics)
            # bf16 gradient compression on the wire happens inside backward;
            # accumulated grads stay f32 for the update.
        new_params, new_opt, om = adamw_update(
            params, grads, state["opt"], state["step"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {**metrics, **om, "loss": metrics.get("loss", 0.0)}

    return train_step


# ---------------------------------------------------------------- driver ----

@dataclasses.dataclass
class TrainRunConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 256
    lr: float = 3e-4
    warmup: int = 20
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: str | None = None
    save_every: int = 50
    keep: int = 3
    log_every: int = 10


class Trainer:
    """End-to-end driver: data -> sharded step -> checkpoint/resume."""

    def __init__(self, model_cfg, run_cfg: TrainRunConfig, mesh=None):
        self.model_cfg = model_cfg
        self.run_cfg = run_cfg
        self.mesh = mesh
        self.opt_cfg = AdamWConfig(
            lr=cosine_warmup(run_cfg.lr, run_cfg.warmup, run_cfg.steps))
        self.stream = TokenStream(model_cfg.vocab, run_cfg.seq_len,
                                  run_cfg.global_batch, seed=run_cfg.seed)
        self.ckpt = (CheckpointManager(run_cfg.ckpt_dir, keep=run_cfg.keep)
                     if run_cfg.ckpt_dir else None)
        step_fn = make_train_step(model_cfg, self.opt_cfg, run_cfg.microbatches)
        if mesh is not None:
            state_sh, batch_sh = train_step_shardings(model_cfg, mesh)
            # Pin output state shardings to the input ones: otherwise GSPMD
            # may pick different layouts for the returned state and the next
            # call's in_shardings reject the donated arrays.
            self._jit_step = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=0)
            self._batch_sh = batch_sh
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=0)
            self._batch_sh = None
        self.state = self._init_or_restore()

    def _fresh_state(self) -> TrainState:
        params = init_params(api.param_specs(self.model_cfg),
                             jax.random.key(self.run_cfg.seed))
        return {"params": params, "opt": init_opt_state(params),
                "step": jnp.zeros((), jnp.int32)}

    def _init_or_restore(self) -> TrainState:
        state = self._fresh_state()
        if self.ckpt:
            restored = self.ckpt.restore_latest(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
            if restored is not None:
                state, extra, step = restored
                if self.mesh is not None:
                    from .elastic import reshard_state
                    state = reshard_state(state, self.model_cfg, self.mesh)
                else:
                    state = jax.tree.map(jnp.asarray, state)
                self.stream.load_state_dict(extra["data"])
                print(f"[trainer] resumed from step {step}")
        return state

    def _device_batch(self, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if self._batch_sh:
            batch = {k: jax.device_put(v, self._batch_sh[k])
                     if k in self._batch_sh else v for k, v in batch.items()}
        return batch

    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps or self.run_cfg.steps
        history = []
        t0 = time.time()
        start = int(self.state["step"])
        for i in range(start, steps):
            batch = self._device_batch(next(self.stream))
            self.state, metrics = self._jit_step(self.state, batch)
            if (i + 1) % self.run_cfg.log_every == 0 or i == start:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                m["wall"] = time.time() - t0
                history.append(m)
                print(f"[trainer] step {i+1} loss {m.get('loss', float('nan')):.4f} "
                      f"gnorm {m.get('grad_norm', 0):.3f} ({m['wall']:.1f}s)")
            if self.ckpt and (i + 1) % self.run_cfg.save_every == 0:
                self.ckpt.save(i + 1, self.state,
                               {"data": self.stream.state_dict()})
        if self.ckpt:
            self.ckpt.save(steps, self.state, {"data": self.stream.state_dict()},
                           block=True)
        return history

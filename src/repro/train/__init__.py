from .trainer import (TrainState, Trainer, TrainRunConfig, make_train_step,
                      train_state_specs, train_step_shardings)
from .elastic import reshard_state, plan_mesh

__all__ = ["TrainState", "Trainer", "TrainRunConfig", "make_train_step",
           "train_state_specs", "train_step_shardings", "reshard_state",
           "plan_mesh"]

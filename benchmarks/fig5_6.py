"""Figures 5-6: BDCD block-size (b') sweep on the Table-3 stand-ins."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bdcd, objective, ridge_exact
from repro.core.cost_model import bdcd_costs
from repro.data import PAPER_DATASETS, make_regression

from ._util import iters_to_accuracy, row

SWEEP = {
    "abalone": [1, 4, 16, 32],
    "news20": [1, 8, 64],
    "a9a": [1, 8, 32, 128],
    "real-sim": [1, 8, 32],
}
H = {"abalone": 2000, "news20": 600, "a9a": 1200, "real-sim": 600}
TARGET = 1e-2
P = 256


def run() -> list[str]:
    jax.config.update("jax_enable_x64", True)
    rows = []
    for name, spec in PAPER_DATASETS.items():
        X, y, _ = make_regression(jax.random.key(7), spec)
        d, n = X.shape
        lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
        w_opt = ridge_exact(X, y, lam)
        f_opt = float(objective(X, w_opt, y, lam))
        for bp in SWEEP[name]:
            bp_eff = min(bp, n)
            res = bdcd(X, y, lam, bp_eff, H[name], jax.random.key(8),
                       w_ref=w_opt)
            rel = (np.asarray(res.history["objective"]) - f_opt) / abs(f_opt)
            it = iters_to_accuracy(rel, TARGET)
            c = bdcd_costs(d, n, P, bp_eff, max(it, 1))
            rows.append(row(
                f"fig5_6/{name}_b{bp_eff}", 0.0,
                f"iters_to_1e-2={it} final_sol_err="
                f"{float(res.history['sol_err'][-1]):.1e} "
                f"F={c.flops:.2e} W={c.bandwidth:.2e} L={c.latency:.2e}"))
    return rows

"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def iters_to_accuracy(history, target: float) -> int:
    """First iteration index reaching relative objective error <= target
    (history = per-iteration objective error array); -1 if never."""
    import numpy as np
    h = np.asarray(history)
    hits = np.nonzero(h <= target)[0]
    return int(hits[0]) + 1 if hits.size else -1

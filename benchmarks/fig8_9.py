"""Figures 8-9: modeled strong/weak scaling of BCD vs CA-BCD on Cori
(MPI + Spark) with the paper's constants, extended with the TPU v5e machine
models (DESIGN.md section 2.5).  Paper claims: strong 14x MPI / 165x Spark
(s = 40 / 600); weak 12x MPI / 396x Spark (s = 25 / 750)."""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import (CORI_MPI, CORI_SPARK, TPU_V5E_DCN,
                                   TPU_V5E_ICI, strong_scaling, weak_scaling)

from ._util import row

PS = [2 ** k for k in range(2, 29)]
SGRID = [1, 2, 5, 10, 25, 40, 50, 100, 200, 300, 400, 600, 750, 1000]


def run() -> list[str]:
    rows = []
    H = 1000
    specs = [
        ("fig8/strong_mpi", CORI_MPI, dict(d=1024, n=2 ** 35), 14),
        ("fig8/strong_spark", CORI_SPARK, dict(d=1024, n=2 ** 40), 165),
    ]
    for name, machine, kw, claim in specs:
        out = strong_scaling(machine, b=4, H=H, Ps=PS, s_grid=SGRID, **kw)
        i = int(np.argmax(out["speedup"]))
        rows.append(row(name, 0.0,
                        f"max_speedup={out['speedup'][i]:.1f}x at P=2^"
                        f"{int(np.log2(out['P'][i]))} s={out['s'][i]} "
                        f"(paper={claim}x)"))
    specs = [
        ("fig9/weak_mpi", CORI_MPI, 12),
        ("fig9/weak_spark", CORI_SPARK, 396),
    ]
    for name, machine, claim in specs:
        out = weak_scaling(machine, d=1024, n_per_P=2 ** 11, b=4, H=H, Ps=PS,
                           s_grid=SGRID)
        i = int(np.argmax(out["speedup"]))
        rows.append(row(name, 0.0,
                        f"max_speedup={out['speedup'][i]:.1f}x at P=2^"
                        f"{int(np.log2(out['P'][i]))} s={out['s'][i]} "
                        f"(paper={claim}x)"))
    # TPU extension: the same transformation pays on the DCN (multi-pod) axis
    for name, machine in (("fig8/strong_tpu_ici", TPU_V5E_ICI),
                          ("fig8/strong_tpu_dcn", TPU_V5E_DCN)):
        out = strong_scaling(machine, d=1024, n=2 ** 35, b=4, H=H,
                             Ps=[2 ** k for k in range(2, 19)], s_grid=SGRID)
        i = int(np.argmax(out["speedup"]))
        rows.append(row(name, 0.0,
                        f"max_speedup={out['speedup'][i]:.1f}x at P=2^"
                        f"{int(np.log2(out['P'][i]))} s={out['s'][i]}"))
    return rows

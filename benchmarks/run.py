"""Benchmark harness aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only MODULE] [--impl I]
    PYTHONPATH=src python -m benchmarks.run --smoke   # tiny shapes, ref mode,
                                                      # writes BENCH_smoke.json

``--smoke`` is the CI perf-trajectory hook (``make bench-smoke``): it runs the
kernel benches on tiny shapes in ref/interpret mode and writes a
``BENCH_smoke.json`` baseline -- wall microseconds per row plus the modeled
HBM bytes/iteration of the panel-free packet vs the gather-then-pack
baseline -- so regressions in either show up as a diff from this PR onward.
Each row records its ``impl``; off-TPU the fused sampled-packet row is
labeled ``wall=ref-proxy`` (the ref backend gathers the panel twice, so its
wall number is not the kernel's wall-clock claim -- only the modeled HBM
ratio is).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = ["table1", "table2", "fig2_3", "fig4", "fig5_6", "fig7", "fig8_9",
           "kernels_bench", "prox_bench", "gram_autotune", "roofline_bench",
           "guard_bench", "serve_bench", "pipeline_bench"]
SMOKE_MODULES = ["kernels_bench", "gram_autotune", "guard_bench",
                 "serve_bench", "pipeline_bench"]
SMOKE_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_smoke.json")


def _run_modules(mods, impl, smoke):
    import inspect

    rows, failures = [], 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            params = inspect.signature(mod.run).parameters
            kw = {}
            if "impl" in params:
                kw["impl"] = impl
            if smoke and "smoke" in params:
                kw["smoke"] = True
            for line in mod.run(**kw):
                print(line, flush=True)
                rows.append(line)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{name},0.0,BENCH_FAILED", flush=True)
            traceback.print_exc()
    return rows, failures


def _write_smoke_baseline(rows, impl, path=SMOKE_OUT):
    import re

    import jax.numpy as jnp

    from repro.core.cost_model import (dual_operand_tradeoff,
                                       packet_traffic_breakdown)
    from repro.kernels.gram import tuning

    from .kernels_bench import PANEL_SHAPE_SMOKE

    d, n, sb = PANEL_SHAPE_SMOKE
    bm = tuning.pick_tiles(sb, n, jnp.float32)[0]
    parsed = []
    for line in rows:
        name, us, derived = line.split(",", 2)
        # Per-row impl (rows embed "impl=<backend>" in their derived field;
        # e.g. the interpret-mode reference row differs from the harness-wide
        # impl), so the regression gate can tell a wall-clock claim from a
        # ref-proxy of the traffic model.
        m = re.search(r"impl=(\S+)", derived)
        parsed.append({"name": name, "us_per_call": float(us),
                       "impl": m.group(1) if m else impl,
                       "derived": derived})
    baseline = {
        "impl": impl,
        "panel_shape": {"sb": sb, "n": n},
        "hbm_bytes_per_iter": packet_traffic_breakdown(sb, n, itemsize=4,
                                                       bm=bm),
        # The dual-layout trade the column-gather operand makes (modeled;
        # the kernels/dual_resident_* rows carry the measured XLA figures).
        "dual_operand_tradeoff": dual_operand_tradeoff(d, n, sb),
        "rows": parsed,
    }
    with open(path, "w") as f:
        json.dump(baseline, f, indent=2, sort_keys=True)
    print(f"# smoke baseline -> {os.path.abspath(path)}", file=sys.stderr)


def _check_registry() -> None:
    """The harness (and the fig/table modules it drives) selects solvers via
    the (formulation, backend) registry; fail fast if an entry went missing
    rather than part-way through a long sweep."""
    from repro.core import FORMULATIONS, registered_solvers
    from repro.core.engine import BACKENDS
    reg = registered_solvers()
    missing = [(f, bk) for f in FORMULATIONS for bk in BACKENDS
               if (f, bk) not in reg]
    if missing:
        raise SystemExit(f"solver registry incomplete: missing {missing}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend forwarded to benches that take "
                         "it: ref | pallas | pallas_interpret")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, kernel benches only; write "
                         "BENCH_smoke.json")
    args = ap.parse_args()
    if args.only:
        mods = [args.only]
    elif args.smoke:
        mods = SMOKE_MODULES
    else:
        mods = MODULES
    impl = args.impl or ("ref" if args.smoke else None)
    _check_registry()
    print("name,us_per_call,derived")
    rows, failures = _run_modules(mods, impl, args.smoke)
    # Only the canonical smoke set may refresh the committed baseline; a
    # --only run with --smoke still uses tiny shapes but never clobbers it.
    if args.smoke and not args.only and not failures:
        _write_smoke_baseline(rows, impl)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness aggregator: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only MODULE]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ["table1", "table2", "fig2_3", "fig4", "fig5_6", "fig7", "fig8_9",
           "kernels_bench", "roofline_bench"]


def main() -> None:
    import inspect

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--impl", default=None,
                    help="Gram-packet backend forwarded to benches that take "
                         "it: ref | pallas | pallas_interpret")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kw = ({"impl": args.impl}
                  if "impl" in inspect.signature(mod.run).parameters else {})
            for line in mod.run(**kw):
                print(line, flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{name},0.0,BENCH_FAILED", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

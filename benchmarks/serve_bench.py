"""Multi-tenant solve throughput: the tenant axis's amortization, measured.

Times ONE batched s-step solve (jitted end to end, fixed index stream) at
T in {1, 64, 4096} tenants and records solves/s = T / wall.  The batched
engine computes the sb x sb Gram packet once per outer step and shares it
across every tenant, so throughput should grow far faster than linearly in
the batch cost: the acceptance line for DESIGN.md section 8 is >= 10x
solves/s at T=64 vs T=1, recorded in BENCH_smoke.json from this PR onward.

Each row's derived field carries the measured solves/s next to the
alpha-beta-gamma model's ``batched_solves_per_second`` (TPU-ICI machine
model -- the modeled number is the production claim, the measured one is
the CPU-backend trajectory guard) and the modeled wire bytes/iter/tenant.

The shape is picked so the SHARED work dominates the per-tenant work
(contraction length >> sb): that is the regime the tenant axis exists for
-- production traffic is many small solves over one big operand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SolverPlan, TenantBatch, s_step_solve_batched, \
    sample_blocks
from repro.core.cost_model import (TPU_V5E_ICI, batched_solves_per_second,
                                   tenant_bytes_per_iter)

from ._util import row, timed

# (d, n, b, s, iters): sb = 256 against an 8192-long contraction.  The
# shared Gram costs ~sb/2 x the per-tenant residual row in flops, so sb is
# the lever that lets the amortization survive the per-tenant overheads
# (the sequential lax.map sweep, the per-block update scan): at sb = 32
# the measured 64v1 ratio is ~6x, at sb = 128 it sits right AT the 10x
# line (CI noise flips it), at sb = 256 it clears 14x with margin.  Smoke
# keeps the same shape -- shrinking it would put the per-tenant sweep in
# charge and the recorded ratio would measure lax.map overhead, not the
# shared packet.
SHAPE = (256, 8192, 32, 8, 8)
SHAPE_SMOKE = SHAPE
TENANTS = (1, 64, 4096)


def _solves_per_s(d, n, b, s, iters, tenants, impl):
    X = jax.random.normal(jax.random.key(0), (d, n), jnp.float32)
    ys = jax.random.normal(jax.random.key(1), (tenants, n), jnp.float32)
    lams = jnp.full((tenants,), 1e-3, jnp.float32)
    idx = sample_blocks(jax.random.key(2), d, b, iters)
    plan = SolverPlan(b=b, s=s, impl=impl)

    @jax.jit
    def solve(X, ys, lams, idx):
        res = s_step_solve_batched("primal", plan,  X,
                                   TenantBatch(ys=ys, lams=lams), iters,
                                   idx=idx)
        return res.ws, res.alphas

    # The T=4096 call runs ~10s on the CPU backend; one timed rep after
    # warmup keeps the bench inside the CI budget.  The small-T rows (the
    # ones the 64v1 ratio reads) take the full median-of-5.
    us = timed(solve, X, ys, lams, idx, iters=1 if tenants > 512 else 5)
    return tenants / (us * 1e-6), us


def run(impl: str | None = None, smoke: bool = False):
    impl = impl or "ref"
    d, n, b, s, iters = SHAPE_SMOKE if smoke else SHAPE
    rates = {}
    for tenants in TENANTS:
        rate, us = _solves_per_s(d, n, b, s, iters, tenants, impl)
        rates[tenants] = rate
        modeled = batched_solves_per_second(
            TPU_V5E_ICI, d=d, n=n, P=1, b=b, H=iters, s=s, tenants=tenants)
        bpt = tenant_bytes_per_iter(d, n, 1, b, s, tenants)
        yield row(f"serve/solves_T{tenants}", us,
                  f"solves_per_s={rate:.1f} modeled_solves_per_s="
                  f"{modeled:.1f} modeled_bytes_per_iter_per_tenant="
                  f"{bpt:.1f} impl={impl}")
    # The amortization headline: one packet, 64 tenants, >= 10x throughput.
    yield row("serve/amortization_64v1", 0.0,
              f"ratio={rates[64] / rates[1]:.1f} target=10x impl={impl}")

"""Figures 2-3: BCD block-size sweep on the four Table-3 stand-ins --
convergence per iteration and the induced flops/bandwidth/latency costs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcd, objective, ridge_exact
from repro.core.cost_model import bcd_costs
from repro.data import PAPER_DATASETS, make_regression

from ._util import iters_to_accuracy, row

SWEEP = {
    "abalone": [1, 2, 4, 6],
    "news20": [1, 8, 32],
    "a9a": [1, 8, 16, 32],
    "real-sim": [1, 8, 16],
}
H = {"abalone": 2000, "news20": 800, "a9a": 1500, "real-sim": 800}
TARGET = 1e-2
P = 256


def run(impl: str | None = None) -> list[str]:
    jax.config.update("jax_enable_x64", True)
    rows = []
    for name, spec in PAPER_DATASETS.items():
        X, y, _ = make_regression(jax.random.key(3), spec)
        d, n = X.shape
        lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
        w_opt = ridge_exact(X, y, lam)
        f_opt = float(objective(X, w_opt, y, lam))
        iters_prev = None
        for b in SWEEP[name]:
            b_eff = min(b, d)
            res = bcd(X, y, lam, b_eff, H[name], jax.random.key(4),
                      w_ref=w_opt, impl=impl)
            rel = (np.asarray(res.history["objective"]) - f_opt) / abs(f_opt)
            it = iters_to_accuracy(rel, TARGET)
            sol = float(res.history["sol_err"][-1])
            c = bcd_costs(d, n, P, b_eff, max(it, 1))
            derived = (f"iters_to_1e-2={it} final_sol_err={sol:.1e} "
                       f"F={c.flops:.2e} W={c.bandwidth:.2e} L={c.latency:.2e}")
            if iters_prev and it > 0 and iters_prev > 0:
                derived += f" iter_reduction_vs_prev_b={iters_prev/it:.2f}"
            iters_prev = it if it > 0 else iters_prev
            rows.append(row(f"fig2_3/{name}_b{b_eff}", 0.0, derived))
    return rows

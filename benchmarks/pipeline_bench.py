"""Pipelined wire-schedule benchmark (DESIGN.md section 9): the alpha-beta
hop model of the ring decomposition vs the monolithic psum, at the dryrun
production mesh, plus a measured equivalence cell on the local CPU world.

The headline row is ``solver/overlap_ratio``: the fraction of the ring
reduction's wire time the pipelined scan hides behind the next step's Gram
contraction and the tenants' sweeps, modeled on the TPU-v5e ICI constants at
the batched serving point (T=4096 tenants, s=8, b=8 on the 16x16 production
mesh).  Single-tenant cells are latency-bound -- 60 hops against sub-
microsecond compute -- so their ratio is honestly near zero and recorded as
such; the acceptance bar (> 0.5) lives where the schedule actually pays.

Rows are modeled (no wire exists off-TPU); the numerical-equivalence claim
behind them (ring == psum to f64 ~1e-12) is machine-checked in
tests/dist_checks.py and the repro.analysis sweep.
"""
from __future__ import annotations

from repro.core.cost_model import (TPU_V5E_ICI, pipeline_schedule,
                                   psum_wire_time, ring_wire_time)

from ._util import row

# The dryrun production mesh (launch/mesh.py): 256 chips as (16, 16).
PROD_AXES = (16, 16)
# The batched serving point of serve_bench / DESIGN.md section 8.
PROD = dict(d=4096, n=1 << 22, b=8, s=8)
TENANTS = 4096


def run(smoke: bool = False) -> list[str]:
    rows = []
    m = TPU_V5E_ICI

    # headline: overlap at the batched serving point (the acceptance row)
    sch = pipeline_schedule(m, axis_sizes=PROD_AXES, tenants=TENANTS, **PROD)
    rows.append(row(
        "solver/overlap_ratio", sch["t_wire_ring"] * 1e6,
        f"overlap_ratio={sch['overlap_ratio']:.3f} tenants={TENANTS} "
        f"mesh={'x'.join(map(str, PROD_AXES))} s={PROD['s']} b={PROD['b']} "
        f"hops={sch['hops']:.0f} modeled=alpha-beta(tpu-v5e-ici)"))
    rows.append(row(
        "solver/exposed_wire_us", sch["t_exposed_ring"] * 1e6,
        f"ring_exposed={sch['t_exposed_ring']*1e6:.1f}us "
        f"psum_exposed={sch['t_exposed_psum']*1e6:.1f}us "
        f"step_speedup={sch['step_speedup']:.2f}x tenants={TENANTS}"))

    # the honest single-tenant cell: latency-bound, near-zero overlap
    sch1 = pipeline_schedule(m, axis_sizes=PROD_AXES, tenants=1, **PROD)
    rows.append(row(
        "solver/overlap_ratio_single", sch1["t_wire_ring"] * 1e6,
        f"overlap_ratio={sch1['overlap_ratio']:.3f} tenants=1 "
        f"(latency-bound: {sch1['hops']:.0f} hops vs "
        f"{sch1['t_compute']*1e6:.2f}us compute)"))

    # raw wire comparison at the packet payload, no overlap credit
    sb = PROD["s"] * PROD["b"]
    payload = sb * sb + TENANTS * sb
    P = PROD_AXES[0] * PROD_AXES[1]
    rows.append(row(
        "solver/wire_ring_vs_psum", ring_wire_time(m, payload, PROD_AXES) * 1e6,
        f"ring_us={ring_wire_time(m, payload, PROD_AXES)*1e6:.1f} "
        f"psum_us={psum_wire_time(m, payload, P)*1e6:.1f} "
        f"payload_words={payload}"))
    return rows

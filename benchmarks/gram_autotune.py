"""Gram-packet tile autotuning sweep: measure (bm, bk) candidates per
(sb, n, dtype) operating point and emit the table ``kernels/gram/tuning.py``
consumes (``tuning.load_table`` / the ``REPRO_GRAM_TUNING`` env var).

On TPU (``--impl pallas``) this times the real kernel and the table entries
are meaningful; on the CPU container the ref backend ignores tile sizes, so
the sweep degenerates to recording the heuristic pick per shape bucket --
the table schema and plumbing are exercised end-to-end either way, and a TPU
run of the same command ships real numbers without code changes.

    PYTHONPATH=src python -m benchmarks.gram_autotune [--out PATH] [--impl I]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_packet, tuning

from ._util import row, timed

# Solver operating points: sb = s*b, n = points (or points/P for the sharded
# local packet).
SHAPES = [(32, 1024), (64, 4096), (128, 4096), (128, 32768)]
SMOKE_SHAPES = [(16, 512)]
DTYPES = [jnp.float32]


def _candidates(m: int, n: int) -> list[tuple[int, int]]:
    cands = [(bm, bk) for bm in tuning.BM_CANDIDATES if bm <= max(m, 8)
             for bk in tuning.BK_CANDIDATES if bk <= max(n, 128)]
    return cands or [(8, 128)]


def sweep(shapes, dtypes, impl: str) -> tuple[list[str], dict]:
    """Returns (CSV rows, table mapping bucket-key -> best (bm, bk))."""
    rows, table = [], {}
    tile_sweep = impl in ("pallas",)  # ref ignores tiles; interpret is Python
    for dtype in dtypes:
        dname = jnp.dtype(dtype).name
        for m, n in shapes:
            A = jax.random.normal(jax.random.key(0), (m, n), dtype)
            u = jax.random.normal(jax.random.key(1), (n,), dtype)
            cands = (_candidates(m, n) if tile_sweep
                     else [tuning.pick_tiles(m, n, dtype)])
            best, best_us = None, float("inf")
            for bm, bk in cands:
                fn = jax.jit(lambda A, u, bm=bm, bk=bk: gram_packet(
                    A, u, scale=1.0 / n, impl=impl, bm=bm, bk=bk))
                us = timed(fn, A, u)
                if us < best_us:
                    best, best_us = (bm, bk), us
            key = (tuning._bucket(tuning._round_up(m, tuning.ROW_GRANULE)),
                   tuning._bucket(tuning._round_up(n, tuning.LANE_GRANULE)),
                   dname)
            table[f"{key[0]},{key[1]},{key[2]}"] = list(best)
            rows.append(row(f"autotune/gram_{m}x{n}_{dname}", best_us,
                            f"bm={best[0]} bk={best[1]} impl={impl} "
                            f"swept={len(cands)}"))
    return rows, table


def write_table(table: dict, impl: str, out: str) -> None:
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump({"backend": impl, "jax_backend": jax.default_backend(),
                   "table": table}, f, indent=2, sort_keys=True)


DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "gram_tuning.json")


def run(impl: str | None = None, smoke: bool = False,
        out: str | None = DEFAULT_OUT) -> list[str]:
    """``out`` defaults to benchmarks/out/gram_tuning.json so harness runs
    (``make bench`` / ``make bench-smoke``) persist the swept table -- on TPU
    that file is exactly what ``REPRO_GRAM_TUNING`` consumes.  Pass
    ``out=None`` to sweep without writing."""
    impl = impl or ("pallas" if jax.default_backend() == "tpu" else "ref")
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows, table = sweep(shapes, DTYPES, impl)
    if out:
        write_table(table, impl, out)
        tuning.register_table(table)   # make this process benefit immediately
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--impl", default=None, help="ref | pallas | pallas_interpret")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(impl=args.impl, smoke=args.smoke, out=args.out):
        print(line)


if __name__ == "__main__":
    main()

"""Gram-packet tile autotuning sweep: measure (bm, bk) candidates per
(sb, n, dtype, layout) operating point and emit the table
``kernels/gram/tuning.py`` consumes (``tuning.load_table`` / the
``REPRO_GRAM_TUNING`` env var).

Both operand layouts are swept: the row-sampled packet (the primal's
operand, timed on the materialized-operand kernel whose tiling it shares)
and the column-sampled packet of the dual's transpose-free operand (timed on
``gram_packet_sampled`` over a ``ColMajorOperand``, the lane-slab gather
kernel).  Tables written by pre-PR-5 sweeps carry three-field keys and load
unchanged, defaulting to row-major.

On TPU (``--impl pallas``) this times the real kernels and the table entries
are meaningful; on the CPU container the ref backend ignores tile sizes, so
the sweep degenerates to recording the heuristic pick per shape bucket --
the table schema and plumbing are exercised end-to-end either way, and a TPU
run of the same command ships real numbers without code changes.

    PYTHONPATH=src python -m benchmarks.gram_autotune [--out PATH] [--impl I]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.kernels.gram import (ColMajorOperand, gram_packet,
                                gram_packet_sampled, tuning)

from ._util import row, timed

# Solver operating points, per layout: (sb, contraction).  Rows: sb = s*b
# against n points (or n/P for the sharded local packet).  Cols: the dual's
# sb' = s*b' against the d-length feature contraction.
SHAPES = [(32, 1024), (64, 4096), (128, 4096), (128, 32768)]
COLS_SHAPES = [(32, 512), (64, 4096)]
SMOKE_SHAPES = [(16, 512)]
SMOKE_COLS_SHAPES = [(16, 256)]
DTYPES = [jnp.float32]


def _candidates(m: int, n: int, layout: str) -> list[tuple[int, int]]:
    bms = (tuning.BM_CANDIDATES if layout == "rows"
           else tuning.BM_CANDIDATES_COLS)
    bks = (tuning.BK_CANDIDATES if layout == "rows"
           else tuning.BK_CANDIDATES_COLS)
    k_floor = 128 if layout == "rows" else 64
    cands = [(bm, bk) for bm in bms if bm <= max(m, 8)
             for bk in bks if bk <= max(n, k_floor)]
    return cands or [(8, k_floor)]


def _timed_case(m: int, n: int, dtype, layout: str, impl: str, bm: int,
                bk: int) -> float:
    if layout == "rows":
        A = jax.random.normal(jax.random.key(0), (m, n), dtype)
        u = jax.random.normal(jax.random.key(1), (n,), dtype)
        fn = jax.jit(lambda A, u: gram_packet(A, u, scale=1.0 / n, impl=impl,
                                              bm=bm, bk=bk))
        return timed(fn, A, u)
    # cols: contraction runs over d = n; samples come from a column pool.
    pool = max(4 * m, 256)
    X = jax.random.normal(jax.random.key(0), (n, pool), dtype)
    u = jax.random.normal(jax.random.key(1), (n,), dtype)
    flat = jax.random.randint(jax.random.key(2), (m,), 0, pool, jnp.int32)
    fn = jax.jit(lambda X, flat, u: gram_packet_sampled(
        ColMajorOperand(X), flat, u, scale=1.0 / n, impl=impl, bm=bm, bk=bk))
    return timed(fn, X, flat, u)


def sweep(shapes_by_layout: dict, dtypes, impl: str) -> tuple[list[str], dict]:
    """Returns (CSV rows, table mapping bucket-key -> best (bm, bk))."""
    rows, table = [], {}
    tile_sweep = impl in ("pallas",)  # ref ignores tiles; interpret is Python
    for dtype in dtypes:
        dname = jnp.dtype(dtype).name
        for layout, shapes in shapes_by_layout.items():
            k_granule = (tuning.LANE_GRANULE if layout == "rows"
                         else tuning.ROW_GRANULE)
            for m, n in shapes:
                cands = (_candidates(m, n, layout) if tile_sweep
                         else [tuning.pick_tiles(m, n, dtype, layout=layout)])
                best, best_us = None, float("inf")
                for bm, bk in cands:
                    us = _timed_case(m, n, dtype, layout, impl, bm, bk)
                    if us < best_us:
                        best, best_us = (bm, bk), us
                key = (tuning._bucket(tuning._round_up(m, tuning.ROW_GRANULE)),
                       tuning._bucket(tuning._round_up(n, k_granule)),
                       dname, layout)
                table[f"{key[0]},{key[1]},{key[2]},{key[3]}"] = list(best)
                rows.append(row(f"autotune/gram_{layout}_{m}x{n}_{dname}",
                                best_us,
                                f"bm={best[0]} bk={best[1]} impl={impl} "
                                f"layout={layout} swept={len(cands)}"))
    return rows, table


def write_table(table: dict, impl: str, out: str) -> None:
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump({"backend": impl, "jax_backend": jax.default_backend(),
                   "table": table}, f, indent=2, sort_keys=True)


DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "gram_tuning.json")


def run(impl: str | None = None, smoke: bool = False,
        out: str | None = DEFAULT_OUT) -> list[str]:
    """``out`` defaults to benchmarks/out/gram_tuning.json so harness runs
    (``make bench`` / ``make bench-smoke``) persist the swept table -- on TPU
    that file is exactly what ``REPRO_GRAM_TUNING`` consumes.  Pass
    ``out=None`` to sweep without writing."""
    impl = impl or ("pallas" if jax.default_backend() == "tpu" else "ref")
    shapes = ({"rows": SMOKE_SHAPES, "cols": SMOKE_COLS_SHAPES} if smoke
              else {"rows": SHAPES, "cols": COLS_SHAPES})
    rows, table = sweep(shapes, DTYPES, impl)
    if out:
        write_table(table, impl, out)
        tuning.register_table(table)   # make this process benefit immediately
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--impl", default=None, help="ref | pallas | pallas_interpret")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(impl=args.impl, smoke=args.smoke, out=args.out):
        print(line)


if __name__ == "__main__":
    main()

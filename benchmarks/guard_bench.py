"""Guard-overhead benchmark: what resilience costs when nothing breaks.

Times the SAME s-step solve (ref backend, jitted end to end) with the
in-scan health guard off and on.  The guard adds a handful of reductions
over data already resident (isfinite counts, a squared norm, a max) plus a
never-taken ``lax.cond`` rescue branch per outer step -- target overhead is
< 3% of the unguarded ref-backend solve, recorded as the
``solver/guard_overhead`` row in BENCH_smoke.json so a regression (e.g. the
guard accidentally forcing an extra packet materialization) shows up as a
baseline diff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bcd import ca_bcd
from repro.core.engine import sample_blocks

from ._util import row, timed

# (d, n, b, s, iters): big enough that the Gram work dominates timer noise,
# small enough for CI.  Unlike the kernel benches, smoke does NOT shrink
# this shape: below a several-ms solve the per-call scheduling jitter on
# shared CI hardware swamps the few-percent effect and the recorded
# overhead row becomes meaningless.  The full shape times in under ~10s.
SHAPE = (256, 1 << 14, 8, 4, 20)
SHAPE_SMOKE = SHAPE


def _paired_us(d, n, b, s, iters, impl, rounds: int = 15):
    """Wall microseconds for the unguarded and guarded solves, measured
    INTERLEAVED (off, on, off, on, ...) and summarized as (min unguarded,
    min unguarded x median per-round on/off ratio).  Pairing each round and
    taking the median ratio cancels CPU frequency / scheduling drift that
    sequential timing cannot -- on a noisy box the raw walls swing +-20%,
    dwarfing the few-percent effect under measurement, but the within-round
    ratio stays put."""
    import statistics
    import time
    X = jax.random.normal(jax.random.key(0), (d, n), jnp.float32)
    y = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    idx = sample_blocks(jax.random.key(2), d, b, iters)

    def make(guard):
        @jax.jit
        def solve(X, y, idx):
            res = ca_bcd(X, y, 1e-3, b, s, iters, None, idx=idx, guard=guard,
                         impl=impl)
            return res.w, res.alpha
        return solve

    fns = {False: make(False), True: make(True)}
    for g in fns:
        jax.block_until_ready(fns[g](X, y, idx))    # compile outside timing
    ratios, best_off = [], float("inf")
    for _ in range(rounds):
        wall = {}
        for g in (False, True):
            t0 = time.perf_counter()
            jax.block_until_ready(fns[g](X, y, idx))
            wall[g] = (time.perf_counter() - t0) * 1e6
        ratios.append(wall[True] / wall[False])
        best_off = min(best_off, wall[False])
    return best_off, best_off * statistics.median(ratios)


def run(impl: str | None = None, smoke: bool = False) -> list[str]:
    impl = impl or "ref"
    d, n, b, s, iters = SHAPE_SMOKE if smoke else SHAPE
    us_off, us_on = _paired_us(d, n, b, s, iters, impl)
    overhead = us_on / us_off - 1.0
    return [
        row("solver/guard_off", us_off,
            f"impl={impl} d={d} n={n} b={b} s={s} iters={iters}"),
        row("solver/guard_overhead", us_on,
            f"impl={impl} overhead={overhead * 100:.2f}% target=<3%"),
    ]


if __name__ == "__main__":
    for line in run():
        print(line)

"""Proximal (elastic-net) formulation bench: what the soft-threshold costs.

Times CA-BCD (ridge) vs CA-PBCD (elastic net, arXiv:1712.06047) end-to-end
through the ``(formulation, backend)`` registry on the same index stream --
both run the identical Gram-packet hot path, so the delta is the prox sweep's
overhead (the extra overlap-corrected ``w`` recurrence + thresholds).  Also
reports the reached sparsity, the quantity the formulation exists to buy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import get_solver, sample_blocks

from ._util import row, timed


def run(impl: str | None = None, smoke: bool = False) -> list[str]:
    impl = impl or "ref"
    d, n, b, s, iters = (128, 1 << 11, 4, 8, 64) if smoke \
        else (512, 1 << 15, 8, 16, 256)
    X = jax.random.normal(jax.random.key(0), (d, n), jnp.float32)
    # sparse ground truth so lam1 has a support to recover
    w_true = jnp.where(jnp.arange(d) % 8 == 0, 1.0, 0.0)
    y = X.T @ w_true + 0.01 * jax.random.normal(jax.random.key(1), (n,))
    lam = 1e-3
    lam1 = 0.1 * float(jnp.max(jnp.abs(X @ y)) / n)
    idx = sample_blocks(jax.random.key(2), d, b, iters)

    ridge = get_solver("primal", "local")
    prox = get_solver("proximal", "local")

    @jax.jit
    def run_ridge(X, y, idx):
        r = ridge(X, y, lam, b, s, iters, None, idx=idx, impl=impl)
        return r.w, r.alpha

    @jax.jit
    def run_prox(X, y, idx):
        r = prox(X, y, lam, b, s, iters, None, idx=idx, lam1=lam1, impl=impl)
        return r.w, r.alpha

    us_ridge = timed(run_ridge, X, y, idx)
    us_prox = timed(run_prox, X, y, idx)
    w, _ = run_prox(X, y, idx)
    nnz = int(jnp.sum(w != 0))
    return [
        row("prox/ca_bcd_ridge", us_ridge,
            f"impl={impl} d={d} n={n} b={b} s={s} iters={iters}"),
        row("prox/ca_pbcd_elastic_net", us_prox,
            f"impl={impl} prox_overhead={us_prox/us_ridge:.2f}x "
            f"nnz={nnz}/{d}"),
    ]

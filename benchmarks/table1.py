"""Table 1: F/L/W/M costs of BCD vs CA-BCD (and BDCD vs CA-BDCD).

Two validations:
  * the alpha-beta-gamma cost model reproduces the table's scaling laws
    (L / s, W * s, F * s, M + s^2 b^2), and
  * the *measured* collective schedule of the compiled distributed solvers
    (8-device subprocess, HLO-counted) matches: #syncs drops by exactly s.
"""
from __future__ import annotations

import os
import subprocess
import sys

from repro.core.cost_model import bcd_costs, bdcd_costs

from ._util import row

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import count_in_compiled, make_solver_mesh
from repro.core.distributed import lower_solver
impl = os.environ.get("REPRO_GRAM_IMPL") or None
mesh = make_solver_mesh(8)
iters = 16
for s in (1, 2, 4, 8):
    comp = lower_solver("primal", mesh, 64, 256, 1e-3, 8, s, iters,
                        fuse_packet=(s > 1), unroll=iters // s, impl=impl)
    c = count_in_compiled(comp)
    print(f"BCD s={s} count={c.count} operand={c.operand_bytes:.0f}")
"""


def run(impl: str | None = None) -> list[str]:
    rows = []
    d, n, P, b, H = 1024, 2 ** 20, 256, 4, 1024
    base = bcd_costs(d, n, P, b, H, 1)
    for s in (2, 8, 32):
        ca = bcd_costs(d, n, P, b, H, s)
        rows.append(row(
            f"table1/bcd_model_s{s}", 0.0,
            f"L_ratio={base.latency/ca.latency:.1f} "
            f"W_ratio={ca.bandwidth/base.bandwidth:.1f} "
            f"F_ratio={ca.flops/base.flops:.2f}"))
    basebd = bdcd_costs(d, n, P, b, H, 1)
    ca = bdcd_costs(d, n, P, b, H, 8)
    rows.append(row("table1/bdcd_model_s8", 0.0,
                    f"L_ratio={basebd.latency/ca.latency:.1f} "
                    f"W_ratio={ca.bandwidth/basebd.bandwidth:.1f}"))

    # measured HLO collective schedule
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    if impl:
        env["REPRO_GRAM_IMPL"] = impl
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode == 0:
        counts = {}
        for line in proc.stdout.splitlines():
            if line.startswith("BCD s="):
                parts = dict(p.split("=") for p in line[4:].split())
                counts[int(parts["s"])] = (int(parts["count"]),
                                           float(parts["operand"]))
        for s, (cnt, opnd) in sorted(counts.items()):
            ratio = counts[1][0] / cnt
            rows.append(row(f"table1/bcd_measured_s{s}", 0.0,
                            f"collectives={cnt} latency_reduction={ratio:.1f}x "
                            f"wire_bytes={opnd:.0f}"))
    else:
        rows.append(row("table1/measured", 0.0,
                        f"SUBPROCESS_FAILED:{proc.stderr[-120:]}"))
    return rows

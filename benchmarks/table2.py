"""Table 2 + Figure 1: BCD / BDCD / CG / TSQR compared on one d > n problem
(news20 stand-in) -- convergence vs flops / bandwidth / latency cost, plus
measured wall time per solver pass on this container."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (bcd, bdcd, cg_ridge_history, objective, ridge_exact,
                        tsqr_ridge)
from repro.core.cost_model import bcd_costs, bdcd_costs, cg_costs, tsqr_costs
from repro.data import PAPER_DATASETS, make_regression

from ._util import iters_to_accuracy, row, timed

TARGET = 1e-2
P = 256


def run() -> list[str]:
    jax.config.update("jax_enable_x64", True)
    spec = PAPER_DATASETS["news20"]  # d > n, like the paper's Figure 1
    X, y, _ = make_regression(jax.random.key(0), spec)
    d, n = X.shape
    lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
    w_opt = ridge_exact(X, y, lam)
    f_opt = float(objective(X, w_opt, y, lam))
    f_0 = float(objective(X, jnp.zeros((d,), X.dtype), y, lam))

    def rel_obj(objs):
        return (np.asarray(objs) - f_opt) / max(abs(f_opt), 1e-300)

    rows = []
    b, bp = 8, 32
    H = 2000
    us_bcd = timed(lambda: bcd(X, y, lam, b, 200, jax.random.key(1)), iters=1)
    res_b = bcd(X, y, lam, b, H, jax.random.key(1), w_ref=w_opt)
    it_b = iters_to_accuracy(rel_obj(res_b.history["objective"]), TARGET)
    rows.append(row("table2/bcd", us_bcd / 200,
                    f"iters_to_1e-2={it_b} "
                    f"modelF={bcd_costs(d, n, P, b, max(it_b, 1)).flops:.2e} "
                    f"modelL={bcd_costs(d, n, P, b, max(it_b, 1)).latency:.2e}"))

    us_bd = timed(lambda: bdcd(X, y, lam, bp, 200, jax.random.key(2)), iters=1)
    res_d = bdcd(X, y, lam, bp, H, jax.random.key(2), w_ref=w_opt)
    it_d = iters_to_accuracy(rel_obj(res_d.history["objective"]), TARGET)
    rows.append(row("table2/bdcd", us_bd / 200,
                    f"iters_to_1e-2={it_d} "
                    f"modelF={bdcd_costs(d, n, P, bp, max(it_d, 1)).flops:.2e} "
                    f"modelL={bdcd_costs(d, n, P, bp, max(it_d, 1)).latency:.2e}"))

    us_cg = timed(lambda: cg_ridge_history(X, y, lam, 50), iters=1)
    res_cg = cg_ridge_history(X, y, lam, 200, w_ref=w_opt)
    it_cg = iters_to_accuracy(rel_obj(res_cg.history["objective"]), TARGET)
    rows.append(row("table2/cg", us_cg / 50,
                    f"iters_to_1e-2={it_cg} "
                    f"modelF={cg_costs(d, n, P, max(it_cg, 1)).flops:.2e} "
                    f"modelL={cg_costs(d, n, P, max(it_cg, 1)).latency:.2e}"))

    us_t = timed(lambda: tsqr_ridge(X, y, lam), iters=1)
    w_t = tsqr_ridge(X, y, lam)
    err_t = float(jnp.linalg.norm(w_t - w_opt) / jnp.linalg.norm(w_opt))
    c_t = tsqr_costs(d, n, P)
    rows.append(row("table2/tsqr", us_t,
                    f"single_pass_err={err_t:.1e} modelF={c_t.flops:.2e} "
                    f"modelL={c_t.latency:.2e}"))

    # Figure 1's qualitative claim: coordinate methods need orders of
    # magnitude more *messages* than CG/TSQR but comparable flops.
    msg_ratio = (bcd_costs(d, n, P, b, max(it_b, 1)).latency /
                 max(tsqr_costs(d, n, P).latency, 1))
    rows.append(row("fig1/messages_bcd_over_tsqr", 0.0, f"ratio={msg_ratio:.1e}"))
    rows.append(row("fig1/start_rel_obj", 0.0,
                    f"{(f_0 - f_opt)/abs(f_opt):.3e}"))
    return rows

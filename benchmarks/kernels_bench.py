"""Gram-kernel micro-benchmark: the paper's BLAS-1/2 -> BLAS-3 insight,
measured (s classical b x b Grams vs ONE (sb x sb) Gram over the same data),
plus the PR-2 panel-free hot path: ``gram_packet_sampled`` + ``panel_apply``
straight from (X, indices) vs the gather-then-``gram_packet`` baseline that
materializes the sampled panel first.  Wall time is XLA CPU here (the Pallas
path targets the TPU MXU with identical tiling); HBM bytes/iteration come
from the cost model's gather-traffic term (``packet_hbm_bytes``), which is
what the roofline uses to predict the win on TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.cost_model import dual_operand_tradeoff, packet_traffic_breakdown
from repro.kernels.gram import (RowMajorOperand, gram_packet,
                                gram_packet_sampled, panel_apply, tuning)

from ._util import row, timed


def _blas3_rows(impl: str, n: int, b: int, s: int) -> list[str]:
    rows = []
    key = jax.random.key(0)
    A_small = [jax.random.normal(jax.random.key(i), (b, n), jnp.float32)
               for i in range(s)]
    A_big = jnp.concatenate(A_small, axis=0)          # (sb, n)
    u = jax.random.normal(key, (n,), jnp.float32)

    @jax.jit
    def classical(blocks, u):
        return [gram_packet(Ab, u, scale=1.0 / n, impl=impl)
                for Ab in blocks]

    @jax.jit
    def ca(Abig, u):
        return gram_packet(Abig, u, scale=1.0 / n, impl=impl)

    us_cl = timed(classical, A_small, u)
    us_ca = timed(ca, A_big, u)
    rows.append(row("kernels/gram_classical_sx_bxb", us_cl,
                    f"impl={impl} s={s} b={b} n={n}"))
    rows.append(row("kernels/gram_ca_one_sbxsb", us_ca,
                    f"impl={impl} blas3_speedup={us_cl/us_ca:.2f}x"))
    return rows


def _panel_free_rows(impl: str, d: int, n: int, sb: int) -> list[str]:
    """Gather-then-packet baseline vs the fused sampled packet, both covering
    the full hot path (packet + deferred vector update)."""
    X = jax.random.normal(jax.random.key(1), (d, n), jnp.float32)
    u = jax.random.normal(jax.random.key(2), (n,), jnp.float32)
    flat = jax.random.randint(jax.random.key(3), (sb,), 0, d, jnp.int32)
    v = jax.random.normal(jax.random.key(4), (sb,), jnp.float32)

    @jax.jit
    def baseline(X, flat, u, v):
        Y = X[flat, :]                                # materialized panel
        G, r = gram_packet(Y, u, scale=1.0 / n, impl=impl)
        return G, r, Y.T @ v                          # apply re-reads Y

    @jax.jit
    def fused(X, flat, u, v):
        G, r = gram_packet_sampled(X, flat, u, scale=1.0 / n, impl=impl)
        return G, r, panel_apply(X, flat, v, impl=impl)

    us_base = timed(baseline, X, flat, u, v)
    us_fused = timed(fused, X, flat, u, v)
    bm = tuning.pick_tiles(sb, n, jnp.float32)[0]
    traffic = packet_traffic_breakdown(sb, n, itemsize=4, bm=bm)
    # Off-TPU the ref backend gathers the panel twice on the fused path (once
    # inside the sampled packet, once inside panel_apply) where the baseline
    # gathers it once and reuses Y, so its wall ratio is an artifact of the
    # ref lowering, not a kernel regression -- printing it (e.g. the old
    # "wall_speedup=0.86x") misled readers into filing perf bugs.  Report the
    # wall number for the real ``pallas`` rows only; everything else carries
    # just the modeled HBM-traffic ratio, which is the row's actual claim.
    if impl == "pallas":
        wall = f"wall_speedup={us_base/us_fused:.2f}x"
    else:
        wall = "wall=ref-proxy(traffic-model-only)"
    rows = [
        row("kernels/sampled_packet_baseline", us_base,
            f"impl={impl} sb={sb} n={n} "
            f"hbm_bytes={traffic['baseline_bytes']:.0f}"),
        row("kernels/sampled_packet_fused", us_fused,
            f"impl={impl} hbm_bytes={traffic['panel_free_bytes']:.0f} "
            f"hbm_ratio={traffic['ratio']:.3f} " + wall),
    ]
    return rows


# (d, n, sb) of the panel-free comparison; run.py's smoke baseline records
# the matching modeled HBM bytes, so keep these in one place.
PANEL_SHAPE = (512, 1 << 15, 128)
PANEL_SHAPE_SMOKE = (128, 1 << 11, 32)


def _dual_resident_rows(impl: str, d: int, n: int) -> list[str]:
    """Peak-resident-bytes of the dual solve: the PR-2..4 pre-transposed
    operand vs the PR-5 column-gather operand, measured from the compiled
    XLA memory analysis (temps + arguments + outputs) with the cost model's
    figures alongside.  Off-TPU the wall number is a ref-proxy as usual --
    the residency comparison is the row's claim."""
    from repro.core import sample_blocks
    from repro.core.engine import DualRidge, SolverPlan, s_step_solve

    class _PreTransposeDual(DualRidge):
        """The PR-2..4 operand strategy (``X.T`` as a row-major operand),
        kept ONLY as this measurement's baseline.  Mirrors
        tests/_legacy_dual.py (not importable here: the bench harness runs
        with only src/ on the path)."""

        def bind(self, X, y, lam, *, x0=None, w_ref=None):
            bound = super().bind(X, y, lam, x0=x0, w_ref=w_ref)
            return dataclasses.replace(
                bound,
                # contract: allow-transpose -- this class IS the
                # pre-transpose baseline being measured against.
                operand=RowMajorOperand(X.T))

    b, s, iters = 8, 4, 8
    X = jax.random.normal(jax.random.key(7), (d, n), jnp.float32)
    y = jax.random.normal(jax.random.key(8), (n,), jnp.float32)
    idx = sample_blocks(jax.random.key(9), n, b, iters)
    plan = SolverPlan(b=b, s=s, impl=impl)

    def _measure(form):
        def f(Xv, yv):
            r = s_step_solve(form, plan, Xv, yv, 1e-3, iters, None, idx=idx)
            return r.w, r.alpha
        comp = jax.jit(f).lower(X, y).compile()
        try:
            ma = comp.memory_analysis()
            resident = int(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                           + ma.output_size_in_bytes)
        except Exception:       # backends without memory stats
            resident = -1
        return timed(lambda: comp(X, y)), resident

    us_pre, res_pre = _measure(_PreTransposeDual())
    us_col, res_col = _measure(DualRidge())
    model = dual_operand_tradeoff(d, n, s * b)
    proxy = "" if impl == "pallas" else " wall=ref-proxy(traffic-model-only)"
    rows = [
        row("kernels/dual_resident_pretranspose", us_pre,
            f"impl={impl} d={d} n={n} resident_bytes={res_pre} "
            f"modeled_resident={model['pretranspose']['resident_bytes']:.0f}"
            + proxy),
        row("kernels/dual_resident_colgather", us_col,
            f"impl={impl} resident_bytes={res_col} "
            f"modeled_resident={model['colgather']['resident_bytes']:.0f} "
            f"resident_ratio="
            f"{(res_col / res_pre if res_pre > 0 else float('nan')):.3f}"
            + proxy),
    ]
    return rows


def run(impl: str | None = None, smoke: bool = False) -> list[str]:
    impl = impl or "ref"
    if smoke:
        n, b, s = 1 << 11, 4, 8
        d, np_, sbp = PANEL_SHAPE_SMOKE
    else:
        n, b, s = 1 << 15, 8, 16
        d, np_, sbp = PANEL_SHAPE
    rows = _blas3_rows(impl, n, b, s)
    rows += _panel_free_rows(impl, d, np_, sbp)
    rows += _dual_resident_rows(impl, d, np_)

    # pallas interpret-mode correctness/latency reference (not a perf number
    # on CPU -- interpret mode executes the kernel body in Python)
    A = jax.random.normal(jax.random.key(5), (s * b, 2048), jnp.float32)
    u2 = jax.random.normal(jax.random.key(6), (2048,), jnp.float32)
    us_pi = timed(lambda: gram_packet(A, u2, scale=1.0 / n,
                                      impl="pallas_interpret"), iters=1)
    rows.append(row("kernels/gram_pallas_interpret_2k", us_pi,
                    "impl=pallas_interpret correctness-path only (CPU)"))
    return rows

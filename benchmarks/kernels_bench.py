"""Gram-kernel micro-benchmark: the paper's BLAS-1/2 -> BLAS-3 insight,
measured.  s classical b x b Grams vs ONE (sb x sb) Gram over the same data
(XLA CPU here; the Pallas path targets the TPU MXU with identical tiling)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_packet

from ._util import row, timed


def run(impl: str | None = None) -> list[str]:
    impl = impl or "ref"
    rows = []
    n = 1 << 15
    b, s = 8, 16
    key = jax.random.key(0)
    A_small = [jax.random.normal(jax.random.key(i), (b, n), jnp.float32)
               for i in range(s)]
    A_big = jnp.concatenate(A_small, axis=0)          # (sb, n)
    u = jax.random.normal(key, (n,), jnp.float32)

    @jax.jit
    def classical(blocks, u):
        return [gram_packet(Ab, u, scale=1.0 / n, impl=impl)
                for Ab in blocks]

    @jax.jit
    def ca(Abig, u):
        return gram_packet(Abig, u, scale=1.0 / n, impl=impl)

    us_cl = timed(classical, A_small, u)
    us_ca = timed(ca, A_big, u)
    rows.append(row("kernels/gram_classical_sx_bxb", us_cl,
                    f"s={s} b={b} n={n}"))
    rows.append(row("kernels/gram_ca_one_sbxsb", us_ca,
                    f"blas3_speedup={us_cl/us_ca:.2f}x"))

    # pallas interpret-mode correctness/latency reference (not a perf number
    # on CPU -- interpret mode executes the kernel body in Python)
    us_pi = timed(lambda: gram_packet(A_big[:, :2048], u[:2048],
                                      scale=1.0 / n, impl="pallas_interpret"),
                  iters=1)
    rows.append(row("kernels/gram_pallas_interpret_2k", us_pi,
                    "correctness-path only (CPU)"))
    return rows

"""Gram-kernel micro-benchmark: the paper's BLAS-1/2 -> BLAS-3 insight,
measured (s classical b x b Grams vs ONE (sb x sb) Gram over the same data),
plus the PR-2 panel-free hot path: ``gram_packet_sampled`` + ``panel_apply``
straight from (X, indices) vs the gather-then-``gram_packet`` baseline that
materializes the sampled panel first.  Wall time is XLA CPU here (the Pallas
path targets the TPU MXU with identical tiling); HBM bytes/iteration come
from the cost model's gather-traffic term (``packet_hbm_bytes``), which is
what the roofline uses to predict the win on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cost_model import packet_traffic_breakdown
from repro.kernels.gram import (gram_packet, gram_packet_sampled, panel_apply,
                                tuning)

from ._util import row, timed


def _blas3_rows(impl: str, n: int, b: int, s: int) -> list[str]:
    rows = []
    key = jax.random.key(0)
    A_small = [jax.random.normal(jax.random.key(i), (b, n), jnp.float32)
               for i in range(s)]
    A_big = jnp.concatenate(A_small, axis=0)          # (sb, n)
    u = jax.random.normal(key, (n,), jnp.float32)

    @jax.jit
    def classical(blocks, u):
        return [gram_packet(Ab, u, scale=1.0 / n, impl=impl)
                for Ab in blocks]

    @jax.jit
    def ca(Abig, u):
        return gram_packet(Abig, u, scale=1.0 / n, impl=impl)

    us_cl = timed(classical, A_small, u)
    us_ca = timed(ca, A_big, u)
    rows.append(row("kernels/gram_classical_sx_bxb", us_cl,
                    f"impl={impl} s={s} b={b} n={n}"))
    rows.append(row("kernels/gram_ca_one_sbxsb", us_ca,
                    f"impl={impl} blas3_speedup={us_cl/us_ca:.2f}x"))
    return rows


def _panel_free_rows(impl: str, d: int, n: int, sb: int) -> list[str]:
    """Gather-then-packet baseline vs the fused sampled packet, both covering
    the full hot path (packet + deferred vector update)."""
    X = jax.random.normal(jax.random.key(1), (d, n), jnp.float32)
    u = jax.random.normal(jax.random.key(2), (n,), jnp.float32)
    flat = jax.random.randint(jax.random.key(3), (sb,), 0, d, jnp.int32)
    v = jax.random.normal(jax.random.key(4), (sb,), jnp.float32)

    @jax.jit
    def baseline(X, flat, u, v):
        Y = X[flat, :]                                # materialized panel
        G, r = gram_packet(Y, u, scale=1.0 / n, impl=impl)
        return G, r, Y.T @ v                          # apply re-reads Y

    @jax.jit
    def fused(X, flat, u, v):
        G, r = gram_packet_sampled(X, flat, u, scale=1.0 / n, impl=impl)
        return G, r, panel_apply(X, flat, v, impl=impl)

    us_base = timed(baseline, X, flat, u, v)
    us_fused = timed(fused, X, flat, u, v)
    bm = tuning.pick_tiles(sb, n, jnp.float32)[0]
    traffic = packet_traffic_breakdown(sb, n, itemsize=4, bm=bm)
    # Off-TPU the wall number is a ref-proxy, not the kernel's claim: the ref
    # backend gathers the panel twice on the fused path (once inside the
    # sampled packet, once inside panel_apply) where the baseline gathers it
    # once and reuses Y, so wall_speedup < 1x here is expected.  The 2x win
    # is the modeled HBM-traffic ratio, which only the DMA-gathering Pallas
    # kernel on real TPU realizes as wall clock.
    wall = f"wall_speedup={us_base/us_fused:.2f}x"
    if impl != "pallas":
        wall += " wall=ref-proxy(traffic-model-only)"
    rows = [
        row("kernels/sampled_packet_baseline", us_base,
            f"impl={impl} sb={sb} n={n} "
            f"hbm_bytes={traffic['baseline_bytes']:.0f}"),
        row("kernels/sampled_packet_fused", us_fused,
            f"impl={impl} hbm_bytes={traffic['panel_free_bytes']:.0f} "
            f"hbm_ratio={traffic['ratio']:.3f} " + wall),
    ]
    return rows


# (d, n, sb) of the panel-free comparison; run.py's smoke baseline records
# the matching modeled HBM bytes, so keep these in one place.
PANEL_SHAPE = (512, 1 << 15, 128)
PANEL_SHAPE_SMOKE = (128, 1 << 11, 32)


def run(impl: str | None = None, smoke: bool = False) -> list[str]:
    impl = impl or "ref"
    if smoke:
        n, b, s = 1 << 11, 4, 8
        d, np_, sbp = PANEL_SHAPE_SMOKE
    else:
        n, b, s = 1 << 15, 8, 16
        d, np_, sbp = PANEL_SHAPE
    rows = _blas3_rows(impl, n, b, s)
    rows += _panel_free_rows(impl, d, np_, sbp)

    # pallas interpret-mode correctness/latency reference (not a perf number
    # on CPU -- interpret mode executes the kernel body in Python)
    A = jax.random.normal(jax.random.key(5), (s * b, 2048), jnp.float32)
    u2 = jax.random.normal(jax.random.key(6), (2048,), jnp.float32)
    us_pi = timed(lambda: gram_packet(A, u2, scale=1.0 / n,
                                      impl="pallas_interpret"), iters=1)
    rows.append(row("kernels/gram_pallas_interpret_2k", us_pi,
                    "impl=pallas_interpret correctness-path only (CPU)"))
    return rows

"""Figure 4: CA-BCD s-sweep -- convergence must MATCH BCD for every s
(the stability claim), with Gram condition-number statistics."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_solver, ridge_exact, sample_blocks
from repro.data import PAPER_DATASETS, make_regression

from ._util import row

BLOCK = {"abalone": 4, "news20": 32, "a9a": 16, "real-sim": 32}
SVALS = [5, 20, 50]
H = 400


def run() -> list[str]:
    jax.config.update("jax_enable_x64", True)
    solve = get_solver("primal", "local")   # s=1 is classical BCD
    rows = []
    for name, spec in PAPER_DATASETS.items():
        X, y, _ = make_regression(jax.random.key(5), spec)
        d, n = X.shape
        lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
        w_opt = ridge_exact(X, y, lam)
        b = min(BLOCK[name], d)
        idx = sample_blocks(jax.random.key(6), d, b, H)
        base = solve(X, y, lam, b, 1, H, None, idx=idx, w_ref=w_opt)
        for s in SVALS:
            res = solve(X, y, lam, b, s, H, None, idx=idx, w_ref=w_opt,
                        track_cond=True)
            dev = np.max(np.abs(np.asarray(res.history["objective"]) -
                                np.asarray(base.history["objective"])))
            scale = max(abs(float(base.history["objective"][-1])), 1e-300)
            cond = np.asarray(res.history["gram_cond"])
            rows.append(row(
                f"fig4/{name}_s{s}", 0.0,
                f"max_obj_dev_rel={dev/scale:.2e} "
                f"gram_cond_med={np.median(cond):.2e} "
                f"gram_cond_max={np.max(cond):.2e} stable={dev/scale < 1e-6}"))
    return rows

"""Roofline summary rows from the dry-run artifacts (section Roofline of
EXPERIMENTS.md is generated from the same data via launch/roofline.py)."""
from __future__ import annotations

import os

from repro.launch.roofline import analyze_cell, load_cells

from ._util import row

ART = os.path.join(os.path.dirname(__file__), os.pardir, "artifacts", "dryrun")


def run() -> list[str]:
    rows = []
    if not os.path.isdir(ART):
        return [row("roofline/missing", 0.0,
                    "run launch/dryrun.py first (artifacts/dryrun)")]
    cells = load_cells(ART)
    for (arch, shape, mesh), slots in sorted(cells.items()):
        if mesh != "single" or "base" not in slots:
            continue
        c = analyze_cell(arch, shape, mesh, slots["base"], slots.get("probe"))
        if c["status"] == "ok":
            rows.append(row(
                f"roofline/{arch}_{shape}",
                max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e6,
                f"dominant={c['dominant']} frac={c['roofline_fraction']:.3f} "
                f"6ND/HLO={c['model_over_hlo']:.3f} "
                f"hbm={c['hbm_gb_per_device']:.1f}GB"))
        elif c["status"] == "skipped":
            rows.append(row(f"roofline/{arch}_{shape}", 0.0, "skipped"))
    return rows

"""Figure 7: CA-BDCD s-sweep -- convergence matches BDCD for all s, Gram
condition statistics stay moderate."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_solver, ridge_exact, sample_blocks
from repro.data import PAPER_DATASETS, make_regression

from ._util import row

BLOCK = {"abalone": 32, "news20": 64, "a9a": 32, "real-sim": 32}
SVALS = [5, 20, 50]
H = 400


def run() -> list[str]:
    jax.config.update("jax_enable_x64", True)
    solve = get_solver("dual", "local")     # s=1 is classical BDCD
    rows = []
    for name, spec in PAPER_DATASETS.items():
        X, y, _ = make_regression(jax.random.key(9), spec)
        d, n = X.shape
        lam = 1e-6 * float(jnp.linalg.norm(X) ** 2)
        w_opt = ridge_exact(X, y, lam)
        b = min(BLOCK[name], n)
        idx = sample_blocks(jax.random.key(10), n, b, H)
        base = solve(X, y, lam, b, 1, H, None, idx=idx, w_ref=w_opt)
        for s in SVALS:
            res = solve(X, y, lam, b, s, H, None, idx=idx, w_ref=w_opt,
                        track_cond=True)
            dev = np.max(np.abs(np.asarray(res.history["objective"]) -
                                np.asarray(base.history["objective"])))
            scale = max(abs(float(base.history["objective"][-1])), 1e-300)
            cond = np.asarray(res.history["gram_cond"])
            rows.append(row(
                f"fig7/{name}_s{s}", 0.0,
                f"max_obj_dev_rel={dev/scale:.2e} "
                f"gram_cond_max={np.max(cond):.2e} stable={dev/scale < 1e-6}"))
    return rows

"""Static contract engine (repro.analysis) tests.

The sweep and the mutation checks need an 8-device world, so they run in
subprocesses via tests/_analysis_checks.py (the pattern of
test_distributed.py / dist_checks.py); the plan- and lint-pass units run
in-process -- neither needs a device (lint needs no jax at all).

The mutation cases are the engine's own acceptance criteria: a seeded second
psum, a registered pre-transpose dual, and an oversized tuning-table entry
must each FAIL the sweep with a message naming the offending op or plan.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "_analysis_checks.py")
_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))


def _run(check: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(_SRC) + os.pathsep + \
        os.path.dirname(__file__) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, _SCRIPT, check], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=_ROOT)
    assert proc.returncode == 0, (
        f"{check} failed:\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert f"{check} OK" in proc.stdout


@pytest.mark.slow
def test_sweep_passes_on_all_registered_solvers():
    """The acceptance gate: every (formulation, backend, impl, fuse_packet,
    ragged) lowering in the registry satisfies its declared contracts."""
    _run("sweep_pass")


def test_mutation_second_psum_caught():
    _run("mutation_second_psum")


def test_mutation_health_guard_caught():
    """A second psum under a claimed health_in_packet contract must fail the
    guard-armed lowerings specifically (the PR-7 zero-extra-collectives
    guarantee)."""
    _run("mutation_health_guard")


def test_mutation_extra_hop_caught():
    """A pipelined lowering sneaking an un-declared psum next to its
    declared collective-permute ring must fail the sweep, naming the op."""
    _run("mutation_extra_hop")


def test_mutation_pretranspose_caught():
    _run("mutation_pretranspose")


def test_mutation_oversized_tile_caught():
    _run("mutation_oversized_tile")


# ---------------------------------------------------------------------------
# plan pass: in-process units (no devices involved)
# ---------------------------------------------------------------------------

def test_plan_pass_clean_on_shipped_table():
    from repro.analysis import run_plan_pass
    rep = run_plan_pass()
    assert rep.ok, rep.violations
    assert len(rep.cases) >= 11  # 9 table entries + 2 layout defaults


def test_check_tiles_flags_vmem_and_alignment():
    from repro.analysis import check_tiles
    # lane-slab amplification: 2*(32*4096*128)*4B ~= 128 MiB >> 16 MiB
    vs = check_tiles(32, 4096, "float32", "cols", "t")
    assert any(v.check == "vmem-budget" for v in vs), vs
    assert any("MiB" in v.message for v in vs)
    # misalignment: bm off the 8-row sublane granule, bk off the lane granule
    vs = check_tiles(12, 120, "float32", "rows", "t")
    kinds = {v.check for v in vs}
    assert kinds == {"tile-alignment"}, vs
    # in-budget aligned tiles are clean in both layouts
    assert not check_tiles(128, 512, "float32", "rows", "t")
    assert not check_tiles(8, 256, "float32", "cols", "t")


def test_check_plan_validates_impl_and_tiles():
    from repro.analysis import check_plan
    from repro.kernels.gram import PacketPlan
    assert not check_plan(PacketPlan(impl="ref", bm=128, bk=512))
    vs = check_plan(PacketPlan(bm=8, bk=4096), layout="cols")
    assert any(v.check == "vmem-budget" for v in vs), vs


# ---------------------------------------------------------------------------
# lint pass: in-process units (no jax needed)
# ---------------------------------------------------------------------------

def test_lint_clean_on_repo_trees():
    from repro.analysis import run_lint
    rep = run_lint(repo_root=_ROOT)
    assert rep.ok, "\n".join(str(v) for v in rep.violations)
    assert len(rep.cases) > 50  # actually swept the trees


def test_lint_catches_pretranspose_formulation():
    """tests/_legacy_dual.py IS the seeded violation: a formulation-shaped
    class binding ``X.T`` with no waiver."""
    from repro.analysis import lint_file
    vs = lint_file(os.path.join(os.path.dirname(__file__), "_legacy_dual.py"))
    assert sum(v.check == "operand-transpose" for v in vs) == 2, vs


def test_lint_catches_raw_collective_and_env_order(tmp_path):
    from repro.analysis import lint_file
    bad = tmp_path / "bad_module.py"
    bad.write_text(
        "import os\n"
        "import jax\n"
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"\n'
        "def f(x):\n"
        "    return jax.lax.psum(x, 'shards')\n")
    vs = lint_file(str(bad))
    checks = {v.check for v in vs}
    assert checks == {"raw-collective", "env-before-jax"}, vs
    # waivers silence both
    ok = tmp_path / "ok_module.py"
    ok.write_text(
        "import os\n"
        'os.environ["XLA_FLAGS"] = "x"\n'
        "import jax\n"
        "def f(x):\n"
        "    return jax.lax.psum(x, 's')  # contract: allow-collective\n")
    assert not lint_file(str(ok))


def test_contracts_hook_declared_by_every_formulation():
    """New formulations must DECLARE their invariants: every registry entry
    exposes contracts() returning a SolverContracts."""
    import repro.core  # noqa: F401  -- registers the built-ins
    from repro.core.engine import FORMULATIONS, SolverContracts
    for name, form in FORMULATIONS.items():
        c = form.contracts()
        assert isinstance(c, SolverContracts), (name, c)
        assert c.sync_per_outer == 1, name  # the paper's headline contract

"""Multi-device checks run in a subprocess with an 8-device CPU world
(tests/test_distributed.py drives this; the main pytest process keeps 1
device).  Each check asserts internally and exits nonzero on failure."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def check_solver_equivalence():
    """Distributed CA solvers == single-device solvers, bit-for-bit blocks."""
    from repro.core import (ca_bcd, ca_bcd_sharded, ca_bdcd, ca_bdcd_sharded,
                            make_solver_mesh, sample_blocks)
    from repro.data import SyntheticSpec, make_regression
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=60, n=200, cond=1e5))
    lam = 1e-3
    mesh = make_solver_mesh(8)
    idx = sample_blocks(jax.random.key(1), 60, 8, 64)
    w_d, al_d = ca_bcd_sharded(mesh, X, y, lam, 8, 8, 64, None, idx=idx)
    r = ca_bcd(X, y, lam, 8, 8, 64, None, idx=idx)
    np.testing.assert_allclose(w_d, r.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(al_d, r.alpha, rtol=1e-11, atol=1e-13)

    idx2 = sample_blocks(jax.random.key(2), 200, 16, 64)
    w_d2, al_d2 = ca_bdcd_sharded(mesh, X, y, lam, 16, 4, 64, None, idx=idx2)
    r2 = ca_bdcd(X, y, lam, 16, 4, 64, None, idx=idx2)
    np.testing.assert_allclose(w_d2, r2.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(al_d2, r2.alpha, rtol=1e-11, atol=1e-13)

    # PR 5: the transpose-free column-gather dual operand is
    # iterate-identical to the PR-2..4 pre-transposed operand on the
    # 8-shard row layout (baseline reconstructed outside the engine in
    # tests/_legacy_dual.py -- the shipped DualRidge no longer transposes).
    from _legacy_dual import LegacyPreTransposeDual
    from repro.core import SolverPlan, s_step_solve_sharded

    w_leg, al_leg = s_step_solve_sharded(
        LegacyPreTransposeDual(), SolverPlan(b=16, s=4), mesh, X, y, lam,
        64, None, idx=idx2)
    np.testing.assert_allclose(w_leg, w_d2, rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(al_leg, al_d2, rtol=1e-12, atol=1e-14)

    # fused packet == unfused (same math, one collective)
    w_f, _ = ca_bcd_sharded(mesh, X, y, lam, 8, 8, 64, None, idx=idx,
                            fuse_packet=False)
    np.testing.assert_allclose(w_f, w_d, rtol=1e-12, atol=1e-14)

    # ragged tail: iters % s != 0 runs a final outer iteration with the
    # remainder blocks through the same engine body -- distributed and
    # single-device agree, and both agree with the classical schedule.
    from repro.core import bcd
    idx3 = sample_blocks(jax.random.key(3), 60, 8, 30)
    w_r, al_r = ca_bcd_sharded(mesh, X, y, lam, 8, 8, 30, None, idx=idx3)
    r_loc = ca_bcd(X, y, lam, 8, 8, 30, None, idx=idx3)
    r_cl = bcd(X, y, lam, 8, 30, None, idx=idx3)
    np.testing.assert_allclose(w_r, r_loc.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(al_r, r_loc.alpha, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(w_r, r_cl.w, rtol=1e-11, atol=1e-13)
    # padding path: d=60, n=200 not divisible by 8 -> padded internally (dual)

    # proximal (elastic-net) formulation: the soft-threshold runs on the
    # replicated post-reduce packet, so sharded == local iterates (ragged s
    # included) with the l1 term active and real zeros in the result.
    from repro.core import ca_proximal_bcd, ca_proximal_bcd_sharded
    lam1 = 0.1 * float(np.max(np.abs(X @ y)) / 200)
    w_p, al_p = ca_proximal_bcd_sharded(mesh, X, y, lam, 8, 8, 30, None,
                                        idx=idx3, lam1=lam1)
    r_p = ca_proximal_bcd(X, y, lam, 8, 8, 30, None, idx=idx3, lam1=lam1)
    np.testing.assert_allclose(w_p, r_p.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(al_p, r_p.alpha, rtol=1e-11, atol=1e-13)
    assert int(np.sum(np.asarray(w_p) != 0)) < 60, "lam1 must induce zeros"
    print("solver_equivalence OK")


def check_pipelined_wire():
    """The pipelined backend (DESIGN.md section 9) against the psum backend.

    Numerics: the ring decomposition sums each packet chunk along ONE fixed
    ring chain and broadcasts the result verbatim, so all shards see
    bit-identical values (replicated-carry consistency) -- but the chain
    order differs from psum's tree order, so pipelined == psum is an f64
    allclose ~1e-12 claim, NOT bit-for-bit.  That looseness is inherent to
    re-associating a float sum and is exactly what the tolerance documents.
    Checked for every registered formulation, even + ragged iters, single +
    batched drivers.

    Wire: the lowering must carry exactly ``H * ring_hops(mesh)`` collective
    -permutes and ZERO all-reduces -- the kind-pinned ``expect_collectives``
    proves the monolithic psum was replaced, not augmented."""
    from repro.core import (ca_accelerated_bcd_pipelined,
                            ca_accelerated_bcd_sharded, ca_bcd_pipelined,
                            ca_bcd_sharded, ca_bdcd_pipelined,
                            ca_bdcd_sharded, ca_proximal_bcd_pipelined,
                            ca_proximal_bcd_sharded, make_solver_mesh,
                            sample_blocks)
    from repro.data import SyntheticSpec, make_regression
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=60, n=200, cond=1e5))
    lam = 1e-3
    mesh = make_solver_mesh(8)
    pairs = {
        "primal": (ca_bcd_pipelined, ca_bcd_sharded, 60, 8, {}),
        "dual": (ca_bdcd_pipelined, ca_bdcd_sharded, 200, 16, {}),
        "proximal": (ca_proximal_bcd_pipelined, ca_proximal_bcd_sharded,
                     60, 8, {"lam1": 1e-3}),
        "accelerated": (ca_accelerated_bcd_pipelined,
                        ca_accelerated_bcd_sharded, 60, 8, {"beta": 0.5}),
    }
    for iters in (64, 30):                       # even and ragged tails
        for name, (ring, psum, dim, b, kw) in pairs.items():
            idx = sample_blocks(jax.random.key(1), dim, b, iters)
            s = 8 if dim == 60 else 4
            w_r, al_r = ring(mesh, X, y, lam, b, s, iters, None, idx=idx, **kw)
            w_p, al_p = psum(mesh, X, y, lam, b, s, iters, None, idx=idx, **kw)
            np.testing.assert_allclose(w_r, w_p, rtol=1e-12, atol=1e-14)
            np.testing.assert_allclose(al_r, al_p, rtol=1e-12, atol=1e-14)
    print("  single-solve equivalence ok (4 formulations, even+ragged)")

    # batched tenants ride the SAME decomposed reduction
    from repro.core import SolverPlan, TenantBatch, s_step_solve_batched_sharded
    from repro.core.engine import PrimalRidge
    T, d, n, b, s, iters = 5, 60, 200, 4, 2, 6
    ys = jnp.stack([jax.random.normal(k, (n,), X.dtype)
                    for k in jax.random.split(jax.random.key(3), T)])
    batch = TenantBatch(ys=ys, lams=jnp.full((T,), lam, X.dtype))
    idxb = sample_blocks(jax.random.key(4), d, b, iters)
    r_p = s_step_solve_batched_sharded(
        PrimalRidge(), SolverPlan(b=b, s=s, tenants=T), mesh, X, batch,
        iters, None, idx=idxb)
    r_r = s_step_solve_batched_sharded(
        PrimalRidge(), SolverPlan(b=b, s=s, tenants=T, wire="ring"), mesh, X,
        batch, iters, None, idx=idxb)
    np.testing.assert_allclose(r_r.ws, r_p.ws, rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(r_r.alphas, r_p.alphas, rtol=1e-12, atol=1e-14)
    print("  batched equivalence ok")

    # the declared wire schedule, machine-counted (kind-pinned): exactly
    # H * ring_hops collective-permutes, zero all-reduces, guard included
    from repro.analysis import expect_collectives
    from repro.core.distributed import lower_solver, lower_solver_batched
    from repro.core.engine import ring_hops
    hops = ring_hops((8,))                       # 2P - 2 = 14 on the 1D mesh
    for iters, H in ((16, 2), (12, 2)):          # 12 % 8 -> ragged H = 2
        comp = lower_solver(ca_bcd_pipelined, mesh, 64, 256, lam, 8, 8,
                            iters, unroll=max(iters // 8, 1))
        expect_collectives(comp, H * hops, kinds=("collective-permute",),
                           subject=f"pipelined primal[iters={iters}]")
    comp = lower_solver("accelerated", mesh, 64, 256, lam, 8, 8, 16,
                        unroll=2, backend="pipelined", beta=0.5, guard=True)
    expect_collectives(comp, 2 * hops, kinds=("collective-permute",),
                       subject="pipelined accelerated[guard]")
    comp = lower_solver_batched("primal", mesh, 64, 256, 8, 4, 2, 4,
                                unroll=2, wire="ring")
    expect_collectives(comp, 2 * hops, kinds=("collective-permute",),
                       subject="pipelined batched[T=8]")
    print("  wire schedule ok: H *", hops, "collective-permutes, 0 psum")
    print("pipelined_wire OK")


def check_collective_counts():
    """The paper's latency claim, measured: #collectives drops by exactly s.

    The baseline is the *fused* classical schedule (s=1, one Gram||residual
    packet per iteration), which guarantees exactly one sync per iteration by
    construction on every XLA version.  The unfused schedule keeps the
    paper's two logical reductions as separate operands but packs them into
    one explicit variadic psum, so since PR 3 it is also exactly one
    all-reduce per outer iteration on every XLA build (asserted below).

    Counting rides ``repro.analysis.expect_collectives`` -- the contract
    engine's assertion API over the one shared HLO parser -- which also pins
    the KIND: exactly N all-reduces and zero of anything else on the wire."""
    from repro.analysis import expect_collectives
    from repro.core import ca_bcd_sharded, ca_bdcd_sharded, count_in_compiled, \
        make_solver_mesh
    from repro.core.distributed import lower_solver
    mesh = make_solver_mesh(8)
    iters, s = 16, 8
    cl = lower_solver(ca_bcd_sharded, mesh, 64, 256, 1e-3, 8, 1, iters,
                      fuse_packet=True, unroll=iters)
    ca = lower_solver(ca_bcd_sharded, mesh, 64, 256, 1e-3, 8, s, iters,
                      fuse_packet=True, unroll=iters // s)
    expect_collectives(cl, iters, subject="bcd classical")  # 1 sync/iteration
    expect_collectives(ca, iters // s, subject="ca-bcd")    # 1 sync/outer
    # the factor-of-s latency claim is exactly these two counts

    # unfused baseline: Gram and residual stay separate operands but ride ONE
    # explicit variadic-psum packet (engine.psum_variadic), so the count no
    # longer depends on whether this XLA build runs the all-reduce combiner.
    # Regression for the PR-3 satellite: exactly one all-reduce per outer
    # iteration, same as the fused schedule.
    unf = lower_solver(ca_bcd_sharded, mesh, 64, 256, 1e-3, 8, 1, iters,
                       fuse_packet=False, unroll=iters)
    expect_collectives(unf, iters, subject="bcd classical unfused")
    unf_ca = lower_solver("primal", mesh, 64, 256, 1e-3, 8, s, iters,
                          fuse_packet=False, unroll=iters // s)
    expect_collectives(unf_ca, iters // s, subject="ca-bcd unfused")

    # dual layout too
    cl2 = lower_solver(ca_bdcd_sharded, mesh, 256, 64, 1e-3, 8, 1, iters,
                       fuse_packet=True, unroll=iters, col_sharded=False)
    ca2 = lower_solver(ca_bdcd_sharded, mesh, 256, 64, 1e-3, 8, s, iters,
                       fuse_packet=True, unroll=iters // s, col_sharded=False)
    expect_collectives(cl2, iters, subject="bdcd classical")
    expect_collectives(ca2, iters // s, subject="ca-bdcd")

    # proximal path: exactly 1 all-reduce per outer iteration with the
    # soft-threshold active (lam1 > 0) -- the nonsmooth term runs on the
    # replicated post-reduce packet and must add ZERO communication.
    prox = lower_solver("proximal", mesh, 64, 256, 1e-3, 8, s, iters,
                        fuse_packet=True, unroll=iters // s, lam1=1e-3)
    expect_collectives(prox, iters // s, subject="ca-proximal")
    prox_cl = lower_solver("proximal", mesh, 64, 256, 1e-3, 8, 1, iters,
                           fuse_packet=False, unroll=iters, lam1=1e-3)
    expect_collectives(prox_cl, iters, subject="proximal classical unfused")

    # bandwidth grows ~s per Table 1: CA op moves ~s^2 b^2 vs s * b^2 words
    b_cl = count_in_compiled(cl).operand_bytes
    b_ca = count_in_compiled(ca).operand_bytes
    assert 2 < b_ca / b_cl < 2 * s, (b_cl, b_ca)
    print("collective_counts OK")


def check_collective_counts_pallas():
    """ROADMAP open item: the one-all-reduce-per-outer-iteration claim
    verified on the *kernel-backend* lowering, not just the CPU ref lowering.

    Off-TPU the sampled Gram kernel runs in interpret mode (the kernel body
    is traced into the lowering, so the fused schedule's collective structure
    is the real one); on TPU the same assertion runs against the actual
    ``impl="pallas"`` Mosaic lowering."""
    from repro.analysis import expect_collectives
    from repro.core import ca_bcd_sharded, ca_bdcd_sharded, make_solver_mesh
    from repro.core.distributed import lower_solver
    mesh = make_solver_mesh(8)
    iters, s = 4, 2
    impls = ["pallas_interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    else:
        print("collective_counts_pallas: no TPU; impl='pallas' branch skipped")
    for impl in impls:
        ca = lower_solver(ca_bcd_sharded, mesh, 16, 256, 1e-3, 4, s, iters,
                          fuse_packet=True, unroll=iters // s, impl=impl)
        expect_collectives(ca, iters // s, subject=f"ca-bcd[{impl}]")
        ca2 = lower_solver(ca_bdcd_sharded, mesh, 256, 64, 1e-3, 4, s, iters,
                           fuse_packet=True, unroll=iters // s,
                           col_sharded=False, impl=impl)
        expect_collectives(ca2, iters // s, subject=f"ca-bdcd[{impl}]")
    print("collective_counts_pallas OK")


def check_batched_collectives():
    """DESIGN.md section 8 on the wire: a T-tenant batched sharded solve
    emits exactly H = ceil(iters/s) all-reduces INDEPENDENT of T, and the
    per-step payload is sb^2 + T*sb words -- the shared Gram packet is not
    scaled by the tenant axis, only the (T, sb) residual directions are."""
    from repro.analysis import expect_collectives
    from repro.core import collective_summary, make_solver_mesh
    from repro.core.distributed import lower_solver_batched
    mesh = make_solver_mesh(8)
    d, n, b, s = 64, 256, 4, 2
    word = 8                                     # x64 subprocess: f64 wire
    for iters in (4, 3):                         # even and ragged tails
        H = iters // s + (1 if iters % s else 0)
        payload = {}
        for tenants in (1, 8, 64):
            comp = lower_solver_batched(
                "primal", mesh, d, n, tenants, b, s, iters,
                unroll=max(iters // s, 1), dtype=jnp.float64)
            expect_collectives(comp, H,
                               subject=f"batched[T={tenants},iters={iters}]")
            payload[tenants] = collective_summary(comp.as_text()).operand_bytes
        for tenants in (8, 64):
            # per-step payload sb_k^2 + T*sb_k with sb_k = s*b on full steps
            # and rem*b on the ragged tail, so the T-scaled part sums to
            # exactly iters*b words per solve -- the Gram part cancels.
            want = payload[1] + (tenants - 1) * word * iters * b
            assert payload[tenants] == want, (
                f"T={tenants} iters={iters}: wire {payload[tenants]} != "
                f"{want} (Gram part must not scale with T)")
    # the dual's per-tenant Gram scale moves post-reduce: same law holds
    comp = lower_solver_batched("dual", mesh, 256, 64, 16, b, s, 4,
                                unroll=2, dtype=jnp.float64)
    expect_collectives(comp, 2, subject="batched dual[T=16]")
    print("batched_collectives OK")


def check_flash_decode():
    """Sequence-sharded flash-decoding == dense decode attention."""
    from repro import compat
    from repro.models.layers import decode_attention, decode_attention_seqsharded
    mesh = compat.make_mesh((8,), ("model",))
    B, S, H, Hkv, Dh = 2, 64, 8, 4, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh), jnp.float32)
    ck = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    cv = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    pos = jnp.asarray([37, 11], jnp.int32)
    # note: dense path broadcasts per-request positions
    dense = decode_attention(q, ck, cv, pos)
    flash = decode_attention_seqsharded(q, ck, cv, pos, mesh=mesh,
                                        axis="model")
    np.testing.assert_allclose(flash, dense, rtol=1e-5, atol=1e-5)

    # and it psums a tiny packet instead of gathering the cache
    from repro.core import collective_summary
    comp = jax.jit(lambda a, b, c: decode_attention_seqsharded(
        a, b, c, pos, mesh=mesh, axis="model")).lower(q, ck, cv).compile()
    s = collective_summary(comp.as_text())
    cache_bytes = 2 * B * S * Hkv * Dh * 4
    assert s.operand_bytes < cache_bytes / 4, s
    print("flash_decode OK")


def check_elastic_reshard():
    """Train on 8 devices, checkpoint, restore on a 4-device mesh, continue."""
    import tempfile
    from repro.configs import get_reduced
    from repro.train import Trainer, TrainRunConfig
    from repro.train.elastic import plan_mesh
    cfg = get_reduced("granite_3_2b")
    with tempfile.TemporaryDirectory() as d:
        rc = TrainRunConfig(steps=2, global_batch=8, seq_len=32, ckpt_dir=d,
                            save_every=2, log_every=1)
        mesh8 = plan_mesh(8, tp=2)
        t1 = Trainer(cfg, rc, mesh=mesh8)
        t1.run()
        # restart on 4 devices (simulated shrink)
        from repro import compat
        mesh4 = compat.device_mesh(
            np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
        rc2 = TrainRunConfig(steps=4, global_batch=8, seq_len=32, ckpt_dir=d,
                             save_every=2, log_every=1)
        t2 = Trainer(cfg, rc2, mesh=mesh4)
        assert int(t2.state["step"]) == 2
        t2.run()
        assert int(t2.state["step"]) == 4
    print("elastic_reshard OK")


CHECKS = {f.__name__.replace("check_", ""): f for f in
          (check_solver_equivalence, check_pipelined_wire,
           check_collective_counts, check_collective_counts_pallas,
           check_batched_collectives, check_flash_decode,
           check_elastic_reshard)}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()

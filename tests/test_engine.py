"""Tests for the s-step engine (PR 3 tentpole): one scan, two formulations.

Covers the wiring the refactor must not break:
  * the engine at s=1 IS the classical algorithm -- checked against an
    independent hand-rolled BCD/BDCD loop (float64), and bit-for-bit against
    the thin ``bcd``/``bdcd`` wrappers;
  * wrapper back-compat: old signatures, warm starts, same ``SolveResult``;
  * ragged ``iters % s != 0`` (including iters < s) matches the classical
    iterates -- the CA identity holds for any grouping of the index stream;
  * ref-vs-pallas_interpret equivalence through the (formulation, backend)
    registry;
  * registry completeness and the SolverPlan -> PacketPlan collapse.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FORMULATIONS, PacketPlan, SolverPlan, bcd, bdcd,
                        ca_bcd, ca_bdcd, get_solver, registered_solvers,
                        s_step_solve, sample_blocks)
from repro.data import SyntheticSpec, make_regression

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=40, n=120, cond=1e4))
    return X, y


# --------------------------------------------------------------------------
# s=1 == the classical algorithm (independent reference)
# --------------------------------------------------------------------------

def _classical_bcd(X, y, lam, b, iters, idx):
    """Algorithm 1, hand-rolled: materialized panel, explicit solve."""
    d, n = X.shape
    w = jnp.zeros((d,), X.dtype)
    alpha = jnp.zeros((n,), X.dtype)
    for h in range(iters):
        i = idx[h]
        Y = X[i, :]
        Gamma = Y @ Y.T / n + lam * jnp.eye(b, dtype=X.dtype)
        r = Y @ (y - alpha) / n - lam * w[i]
        dw = jnp.linalg.solve(Gamma, r)
        w = w.at[i].add(dw)
        alpha = alpha + Y.T @ dw
    return w, alpha


def _classical_bdcd(X, y, lam, b, iters, idx):
    """Algorithm 3, hand-rolled."""
    d, n = X.shape
    alpha = jnp.zeros((n,), X.dtype)
    w = jnp.zeros((d,), X.dtype)
    for h in range(iters):
        i = idx[h]
        Y = X[:, i]
        Theta = Y.T @ Y / (lam * n * n) + jnp.eye(b, dtype=X.dtype) / n
        rhs = (Y.T @ w - alpha[i] - y[i]) / n
        da = jnp.linalg.solve(Theta, rhs)
        alpha = alpha.at[i].add(da)
        w = w - Y @ da / (lam * n)
    return w, alpha


def test_engine_s1_is_classical_bcd(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(1), X.shape[0], 4, 20)
    res = s_step_solve("primal", SolverPlan(b=4, s=1), X, y, LAM, 20,
                       None, idx=idx)
    w_ref, al_ref = _classical_bcd(X, y, LAM, 4, 20, idx)
    np.testing.assert_allclose(res.w, w_ref, rtol=0, atol=1e-12)
    np.testing.assert_allclose(res.alpha, al_ref, rtol=0, atol=1e-12)


def test_engine_s1_is_classical_bdcd(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(2), X.shape[1], 4, 20)
    res = s_step_solve("dual", SolverPlan(b=4, s=1), X, y, LAM, 20,
                       None, idx=idx)
    w_ref, al_ref = _classical_bdcd(X, y, LAM, 4, 20, idx)
    np.testing.assert_allclose(res.w, w_ref, rtol=0, atol=1e-12)
    np.testing.assert_allclose(res.alpha, al_ref, rtol=0, atol=1e-12)


def test_wrappers_are_the_engine_bit_for_bit(problem):
    """bcd/bdcd delegate to s_step_solve with NO numerical detour."""
    X, y = problem
    idx = sample_blocks(jax.random.key(3), X.shape[0], 4, 16)
    r_wrap = bcd(X, y, LAM, 4, 16, None, idx=idx)
    r_eng = s_step_solve("primal", SolverPlan(b=4, s=1), X, y, LAM, 16,
                         None, idx=idx)
    assert np.array_equal(np.asarray(r_wrap.w), np.asarray(r_eng.w))
    assert np.array_equal(np.asarray(r_wrap.alpha), np.asarray(r_eng.alpha))

    idx2 = sample_blocks(jax.random.key(4), X.shape[1], 4, 16)
    r_wrap2 = bdcd(X, y, LAM, 4, 16, None, idx=idx2)
    r_eng2 = s_step_solve("dual", SolverPlan(b=4, s=1), X, y, LAM, 16,
                          None, idx=idx2)
    assert np.array_equal(np.asarray(r_wrap2.w), np.asarray(r_eng2.w))
    assert np.array_equal(np.asarray(r_wrap2.alpha), np.asarray(r_eng2.alpha))


# --------------------------------------------------------------------------
# Wrapper back-compat
# --------------------------------------------------------------------------

def test_wrapper_backcompat_signatures(problem):
    """The PR-2 call shapes keep working: positional core args, keyword
    extras, SolveResult fields, per-iteration history lengths."""
    X, y = problem
    res = bcd(X, y, LAM, 8, 12, jax.random.key(5))
    # PR 7 appended the defaulted ``metrics`` field; the PR-2 prefix is
    # pinned so positional access keeps meaning what it always did.
    assert res._fields == ("w", "alpha", "history", "metrics")
    assert res._fields[:3] == ("w", "alpha", "history")
    assert res.metrics == {}                     # unguarded: no telemetry
    assert res.w.shape == (X.shape[0],) and res.alpha.shape == (X.shape[1],)
    assert res.history["objective"].shape == (12,)

    res = ca_bcd(X, y, LAM, 4, 3, 12, jax.random.key(6), track_cond=True)
    assert res.history["objective"].shape == (12,)
    assert res.history["gram_cond"].shape == (12,)

    res = ca_bdcd(X, y, LAM, 4, 3, 12, jax.random.key(7),
                  w_ref=jnp.ones((X.shape[0],), X.dtype))
    assert res.history["sol_err"].shape == (12,)


def test_warm_start_matches_continuation(problem):
    """w0 warm start == running the first half then the second half."""
    X, y = problem
    idx = sample_blocks(jax.random.key(8), X.shape[0], 4, 20)
    full = bcd(X, y, LAM, 4, 20, None, idx=idx)
    half = bcd(X, y, LAM, 4, 10, None, idx=idx[:10])
    rest = bcd(X, y, LAM, 4, 10, None, idx=idx[10:], w0=half.w)
    np.testing.assert_allclose(rest.w, full.w, rtol=1e-11, atol=1e-13)

    idx2 = sample_blocks(jax.random.key(9), X.shape[1], 4, 20)
    full2 = bdcd(X, y, LAM, 4, 20, None, idx=idx2)
    half2 = bdcd(X, y, LAM, 4, 10, None, idx=idx2[:10])
    rest2 = bdcd(X, y, LAM, 4, 10, None, idx=idx2[10:], alpha0=half2.alpha)
    np.testing.assert_allclose(rest2.w, full2.w, rtol=1e-11, atol=1e-13)


# --------------------------------------------------------------------------
# Ragged iters % s != 0 (the former ValueError)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("iters,s", [(10, 4), (7, 3), (3, 8), (25, 25)])
def test_ragged_ca_bcd_matches_classical(problem, iters, s):
    X, y = problem
    idx = sample_blocks(jax.random.key(10), X.shape[0], 4, iters)
    r_cl = bcd(X, y, LAM, 4, iters, None, idx=idx)
    r_ca = ca_bcd(X, y, LAM, 4, s, iters, None, idx=idx)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.alpha, r_cl.alpha, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.history["objective"],
                               r_cl.history["objective"], rtol=1e-9, atol=0)


@pytest.mark.parametrize("iters,s", [(10, 4), (5, 2)])
def test_ragged_ca_bdcd_matches_classical(problem, iters, s):
    X, y = problem
    idx = sample_blocks(jax.random.key(11), X.shape[1], 4, iters)
    r_cl = bdcd(X, y, LAM, 4, iters, None, idx=idx)
    r_ca = ca_bdcd(X, y, LAM, 4, s, iters, None, idx=idx)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.alpha, r_cl.alpha, rtol=1e-11, atol=1e-13)


def test_idx_length_mismatch_rejected(problem):
    """An explicit idx must cover exactly (iters, b) -- the pre-engine CA
    solvers raised via their reshape; the engine keeps that contract instead
    of silently running idx's own length."""
    X, y = problem
    idx = sample_blocks(jax.random.key(20), X.shape[0], 4, 8)
    with pytest.raises(ValueError, match="does not match"):
        ca_bcd(X, y, LAM, 4, 2, 16, None, idx=idx)
    with pytest.raises(ValueError, match="does not match"):
        bcd(X, y, LAM, 8, 8, None, idx=idx)   # b mismatch


def test_ragged_track_cond_history_length(problem):
    """gram_cond spans main scan + ragged tail: one entry per inner iter."""
    X, y = problem
    res = ca_bcd(X, y, LAM, 4, 4, 10, jax.random.key(12), track_cond=True)
    assert res.history["gram_cond"].shape == (10,)
    assert np.all(np.isfinite(res.history["gram_cond"]))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

def test_registry_complete():
    reg = registered_solvers()
    for form in FORMULATIONS:
        for backend in ("local", "sharded"):
            assert (form, backend) in reg
    with pytest.raises(KeyError, match="no solver registered"):
        get_solver("kernelized", "local")


@pytest.mark.parametrize("form", ["primal", "dual"])
def test_registry_ref_vs_interpret(problem, form):
    """ref-vs-pallas_interpret equivalence straight through the registry
    (ragged s so the tail also runs both backends)."""
    X, y = problem
    solve = get_solver(form, "local")
    dim = X.shape[0] if form == "primal" else X.shape[1]
    idx = sample_blocks(jax.random.key(13), dim, 4, 10)
    r_ref = solve(X, y, LAM, 4, 4, 10, None, idx=idx, impl="ref")
    r_pi = solve(X, y, LAM, 4, 4, 10, None, idx=idx, impl="pallas_interpret")
    np.testing.assert_allclose(r_pi.w, r_ref.w, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.alpha, r_ref.alpha, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.history["objective"],
                               r_ref.history["objective"], rtol=1e-10, atol=0)


# --------------------------------------------------------------------------
# SolverPlan -> PacketPlan collapse
# --------------------------------------------------------------------------

def test_solver_plan_packet():
    plan = SolverPlan(b=8, s=4, impl="ref", tiles=(16, 256))
    assert plan.packet == PacketPlan(impl="ref", bm=16, bk=256)
    assert SolverPlan(b=8).packet == PacketPlan()
    assert PacketPlan.make(impl="pallas") == PacketPlan(impl="pallas")


def test_plans_fail_fast_on_bad_knobs():
    """Regression (PR-4 satellite): a typo'd impl or a zero tile used to
    surface only at the first kernel call inside the jitted scan (or fall
    through to the plan's tiles); both now raise at plan construction."""
    with pytest.raises(ValueError, match="unknown gram impl"):
        SolverPlan(b=8, impl="palas")                     # the typo'd knob
    with pytest.raises(ValueError, match="unknown gram impl"):
        PacketPlan(impl="cuda")
    with pytest.raises(ValueError, match="unknown gram impl"):
        PacketPlan.make(impl="REF")
    with pytest.raises(ValueError, match="positive int"):
        PacketPlan(bm=0)
    with pytest.raises(ValueError, match="positive int"):
        SolverPlan(b=8, tiles=(16, 0))
    with pytest.raises(ValueError, match=r"\(bm, bk\) pair"):
        SolverPlan(b=8, tiles=(16,))
    with pytest.raises(ValueError, match="must be a positive int"):
        SolverPlan(b=0)
    with pytest.raises(ValueError, match="must be a positive int"):
        SolverPlan(b=8, s=0)


def test_explicit_zero_tile_rejected_per_call(problem):
    """bm=0 used to falsy-fall-through to the plan's tiles; now it is an
    error at the call site, plan or no plan."""
    from repro.core import gram_packet_sampled
    X, _ = problem
    flat = jnp.arange(8, dtype=jnp.int32)
    u = jnp.ones((X.shape[1],), X.dtype)
    with pytest.raises(ValueError, match="bm=0"):
        gram_packet_sampled(X, flat, u, plan=PacketPlan(impl="ref", bm=16),
                            bm=0)
    with pytest.raises(ValueError, match="bk=-4"):
        gram_packet_sampled(X, flat, u, bk=-4)


def test_packet_plan_explicit_kwargs_win(problem):
    """A per-call impl/bm/bk overrides the plan's bundled defaults."""
    from repro.core import gram_packet_sampled
    X, y = problem
    flat = jnp.arange(8, dtype=jnp.int32)
    u = jnp.ones((X.shape[1],), X.dtype)
    plan = PacketPlan(impl="ref")
    G0, r0 = gram_packet_sampled(X, flat, u, plan=plan)
    G1, r1 = gram_packet_sampled(X, flat, u, plan=plan,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(G1, G0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r1, r0, rtol=0, atol=1e-10)
    with pytest.raises(ValueError, match="unknown gram impl"):
        gram_packet_sampled(X, flat, u, plan=PacketPlan(impl="cuda"))

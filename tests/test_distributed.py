"""Multi-device (8 simulated CPU devices) integration tests.  Each case runs
in a subprocess so the main pytest world stays at 1 device (the dry-run's 512
likewise lives in its own process)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "dist_checks.py")
_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(check: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(_SRC) + os.pathsep + \
        os.path.dirname(__file__) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, _SCRIPT, check], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (
        f"{check} failed:\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert f"{check} OK" in proc.stdout


def test_distributed_solver_equivalence():
    _run("solver_equivalence")


def test_pipelined_wire_schedule():
    """Pipelined backend == psum backend to f64 ~1e-12 (reduction order
    differs, so not bit-for-bit) for every registered formulation, single +
    batched, with the declared collective-permute ring machine-counted."""
    _run("pipelined_wire")


def test_collective_count_reduction_by_s():
    _run("collective_counts")


def test_collective_count_pallas_lowering():
    """One all-reduce per outer iteration on the kernel-backend lowering
    (interpret off-TPU; the real Mosaic lowering on TPU)."""
    _run("collective_counts_pallas")


def test_batched_collectives_independent_of_tenants():
    """T-tenant batched lowering: exactly H = ceil(iters/s) all-reduces at
    T in {1, 8, 64}, per-step payload sb^2 + T*sb words (shared Gram not
    scaled by T)."""
    _run("batched_collectives")


def test_flash_decode_seqsharded():
    _run("flash_decode")


@pytest.mark.slow
def test_elastic_reshard():
    _run("elastic_reshard")

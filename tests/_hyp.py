"""Optional-hypothesis shim.

Import ``given`` / ``settings`` / ``st`` from here instead of ``hypothesis``:
when hypothesis is installed they are the real thing; when it is not, every
``@given(...)``-decorated test collects normally and skips with a clear
reason, so the rest of the module (and the tier-1 suite) still runs.
"""
import pytest

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False
    HealthCheck = None

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None, enough to evaluate module-level strategy
        expressions like ``st.integers(1, 5)``."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed; "
                                     "property test skipped")
            def skipper():
                pass
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

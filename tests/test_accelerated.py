"""Registry-level tests for the accelerated (momentum) formulation
(arXiv:1711.05305) -- the satellite of the pipelined-wire PR.

Covers the acceptance criteria:
  * ``beta=0`` reproduces the primal ridge iterates BIT-FOR-BIT through
    ``get_solver`` (static branch: the momentum update lowers to the primal
    update itself), s=1 and s>1, even + ragged schedules;
  * s=1 matches a hand-rolled classical heavy-ball BCD oracle (momentum
    applied per block, shared no code with the engine);
  * s>1 applies momentum to the DEFERRED updates (the CoCoA-style local-
    subproblem semantics the formulation documents -- NOT an exact
    reordering of the s=1 schedule) and still reaches the ridge optimum;
  * momentum at beta in (0, 1) still converges to the ridge optimum (the
    velocity is a convergence accelerant, not a different fixed point);
  * beta outside [0, 1) fails fast;
  * the registry carries all three backends.
(The sharded + pipelined equivalences run on the 8-device subprocess in
tests/dist_checks.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (accelerated_bcd, ca_accelerated_bcd, get_solver,
                        objective, ridge_exact, sample_blocks)
from repro.core.accelerated import MomentumWrapper

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    from repro.data import SyntheticSpec, make_regression
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=40, n=120, cond=1e4))
    return X, y


# --------------------------------------------------------------------------
# beta = 0 IS the primal ridge, bit-for-bit, through the registry
# --------------------------------------------------------------------------

def test_beta_zero_is_primal_bit_for_bit(problem):
    X, y = problem
    acc = get_solver("accelerated", "local")
    ridge = get_solver("primal", "local")
    for iters, s in ((20, 1), (20, 4), (21, 4)):       # classical, CA, ragged
        idx = sample_blocks(jax.random.key(1), X.shape[0], 4, iters)
        r_a = acc(X, y, LAM, 4, s, iters, None, idx=idx, beta=0.0)
        r_p = ridge(X, y, LAM, 4, s, iters, None, idx=idx)
        assert np.array_equal(np.asarray(r_a.w), np.asarray(r_p.w)), (iters, s)
        assert np.array_equal(np.asarray(r_a.alpha), np.asarray(r_p.alpha))


# --------------------------------------------------------------------------
# s=1 == a hand-rolled classical heavy-ball BCD oracle
# --------------------------------------------------------------------------

def _momentum_bcd_reference(X, y, lam, beta, b, iters, idx):
    """Classical heavy-ball BCD: materialized panel, explicit solve, velocity
    applied per block.  Deliberately shares no code with the engine path."""
    d, n = X.shape
    w = jnp.zeros((d,), X.dtype)
    alpha = jnp.zeros((n,), X.dtype)
    v = jnp.zeros((d,), X.dtype)
    for h in range(iters):
        i = idx[h]
        Y = X[i, :]
        Gamma = Y @ Y.T / n + lam * jnp.eye(b, dtype=X.dtype)
        r = Y @ (y - alpha) / n - lam * w[i]
        dx = jnp.linalg.solve(Gamma, r)
        vi = beta * v[i] + dx
        v = v.at[i].set(vi)
        w = w.at[i].add(vi)
        alpha = alpha + Y.T @ vi
    return w, alpha


@pytest.mark.parametrize("iters", [24, 25])
def test_s1_is_classical_heavy_ball(problem, iters):
    X, y = problem
    idx = sample_blocks(jax.random.key(2), X.shape[0], 4, iters)
    res = accelerated_bcd(X, y, LAM, 4, iters, None, idx=idx, beta=0.7)
    w_ref, al_ref = _momentum_bcd_reference(X, y, LAM, 0.7, 4, iters, idx)
    np.testing.assert_allclose(res.w, w_ref, rtol=0, atol=1e-12)
    np.testing.assert_allclose(res.alpha, al_ref, rtol=0, atol=1e-12)


# --------------------------------------------------------------------------
# momentum converges to the ridge optimum (same fixed point)
# --------------------------------------------------------------------------

def test_momentum_converges_to_ridge_optimum(problem):
    X, y = problem
    w_star = ridge_exact(X, y, LAM)
    o_star = float(objective(X, w_star, y, LAM))
    for s in (1, 4):                    # classical and deferred-update paths
        r = ca_accelerated_bcd(X, y, LAM, 4, s, 400, jax.random.key(3),
                               beta=0.5)
        gap = float(objective(X, r.w, y, LAM)) - o_star
        assert -1e-12 <= gap < 1e-6, (s, gap)


def test_velocity_is_carry_state_not_output(problem):
    """The solve returns the standard (w, alpha) result shape -- the
    velocity stays in the scan carry and is dropped by the finalizer."""
    X, y = problem
    r = ca_accelerated_bcd(X, y, LAM, 4, 2, 8, jax.random.key(4), beta=0.9)
    assert r.w.shape == (X.shape[0],)
    assert r.alpha.shape == (X.shape[1],)
    assert jnp.all(jnp.isfinite(r.w))


# --------------------------------------------------------------------------
# validation + registry coverage
# --------------------------------------------------------------------------

def test_bad_beta_fails_fast():
    with pytest.raises(ValueError, match="beta"):
        MomentumWrapper(beta=1.0)
    with pytest.raises(ValueError, match="beta"):
        MomentumWrapper(beta=-0.1)


def test_registered_on_all_backends():
    from repro.core import registered_solvers
    backends = {b for (name, b) in registered_solvers()
                if name == "accelerated"}
    assert backends == {"local", "sharded", "pipelined"}, backends


def test_contract_declares_momentum_lowering():
    """The analysis sweep must lower the beta>0 path, not the beta=0 primal
    branch -- the contract pins that via lowering_kwargs."""
    c = MomentumWrapper().contracts()
    assert ("beta", 0.5) in c.lowering_kwargs
    assert c.sync_per_outer == 1
    assert c.pipelined_collective_kinds == ("collective-permute",)

"""HLO collective-parser unit tests against synthetic and real HLO text."""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.hlo_analysis import collective_summary, parse_collectives

SYNTH = """
HloModule test
%x = f32[128,64]{1,0} parameter(0)
%ar = f32[128,64]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
%ag = bf16[256,64]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
%rs = f32[16,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
%cp = f32[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
%done = f32[4]{0} all-reduce-done(%start)
%a2a = f32[8,8]{1,0} all-to-all(%v), channel_id=5, replica_groups=[2,4]<=[8], dimensions={0}
"""


def test_parse_kinds_and_counts():
    ops = parse_collectives(SYNTH)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_operand_byte_conventions():
    ops = {o.kind: o for o in parse_collectives(SYNTH)}
    assert ops["all-reduce"].operand_bytes == 128 * 64 * 4
    # all-gather operand = result / group_size (group 4)
    assert ops["all-gather"].operand_bytes == 256 * 64 * 2 / 4
    # reduce-scatter operand = result * group_size (group 8)
    assert ops["reduce-scatter"].operand_bytes == 16 * 64 * 4 * 8
    assert ops["collective-permute"].operand_bytes == 128 * 4
    assert ops["all-to-all"].operand_bytes == 8 * 8 * 4


def test_ring_model_bytes():
    ops = {o.kind: o for o in parse_collectives(SYNTH)}
    # AR ring: 2 (g-1)/g * bytes, g=4
    assert abs(ops["all-reduce"].link_bytes - 2 * 0.75 * 128 * 64 * 4) < 1
    assert ops["all-reduce"].group_size == 4


def test_done_ops_skipped():
    assert all(o.kind != "all-reduce-done" for o in parse_collectives(SYNTH))


def test_summary_aggregation():
    s = collective_summary(SYNTH)
    assert s.count == 5
    assert s.operand_bytes > 0 and s.link_bytes > 0
    assert set(s.by_kind) == {"all-gather", "all-reduce", "all-to-all",
                              "collective-permute", "reduce-scatter"}


def test_real_hlo_psum():
    """End-to-end on real compiled HLO (1-device mesh still emits the op
    structure when contracted over a sharded axis on multi-dev meshes; here we
    just assert the parser tolerates real output)."""
    mesh = compat.make_mesh((1,), ("d",))
    f = jax.jit(lambda x: x @ x.T,
                in_shardings=NamedSharding(mesh, P(None, "d")))
    comp = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    s = collective_summary(comp.as_text())
    assert s.count >= 0  # parser never crashes on real HLO

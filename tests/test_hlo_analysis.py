"""HLO parser unit tests against synthetic and real HLO text.

The ``tests/fixtures/hlo/*.txt`` files are line sets captured from REAL
JAX 0.4.37 CPU-backend lowerings of the solvers (provenance in each file's
header), so the conventions the parser encodes -- brace-form replica_groups,
``-start`` tuple halving, gather-absorbing fusion names -- are pinned
without a live multi-device compile in this test process.
"""
import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.hlo_analysis import (collective_dtypes, collective_summary,
                                     parse_collectives, parse_named_ops)

_FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def _fixture(name: str) -> str:
    with open(os.path.join(_FIXTURES, name), encoding="utf-8") as f:
        return f.read()

SYNTH = """
HloModule test
%x = f32[128,64]{1,0} parameter(0)
%ar = f32[128,64]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
%ag = bf16[256,64]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
%rs = f32[16,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
%cp = f32[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
%done = f32[4]{0} all-reduce-done(%start)
%a2a = f32[8,8]{1,0} all-to-all(%v), channel_id=5, replica_groups=[2,4]<=[8], dimensions={0}
"""


def test_parse_kinds_and_counts():
    ops = parse_collectives(SYNTH)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_operand_byte_conventions():
    ops = {o.kind: o for o in parse_collectives(SYNTH)}
    assert ops["all-reduce"].operand_bytes == 128 * 64 * 4
    # all-gather operand = result / group_size (group 4)
    assert ops["all-gather"].operand_bytes == 256 * 64 * 2 / 4
    # reduce-scatter operand = result * group_size (group 8)
    assert ops["reduce-scatter"].operand_bytes == 16 * 64 * 4 * 8
    assert ops["collective-permute"].operand_bytes == 128 * 4
    assert ops["all-to-all"].operand_bytes == 8 * 8 * 4


def test_ring_model_bytes():
    ops = {o.kind: o for o in parse_collectives(SYNTH)}
    # AR ring: 2 (g-1)/g * bytes, g=4
    assert abs(ops["all-reduce"].link_bytes - 2 * 0.75 * 128 * 64 * 4) < 1
    assert ops["all-reduce"].group_size == 4


def test_done_ops_skipped():
    assert all(o.kind != "all-reduce-done" for o in parse_collectives(SYNTH))


def test_summary_aggregation():
    s = collective_summary(SYNTH)
    assert s.count == 5
    assert s.operand_bytes > 0 and s.link_bytes > 0
    assert set(s.by_kind) == {"all-gather", "all-reduce", "all-to-all",
                              "collective-permute", "reduce-scatter"}


def test_start_tuple_result_halved():
    """Async ``-start`` results are (operand(s), result(s)) tuples: counted
    once, at half the summed tuple bytes; the paired ``-done`` is skipped."""
    synth = (
        "%ars = (f32[8,9]{1,0}, f32[8,9]{1,0}) all-reduce-start(%p), "
        "channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}\n"
        "%ard = f32[8,9]{1,0} all-reduce-done(%ars)\n")
    ops = parse_collectives(synth)
    assert len(ops) == 1
    assert ops[0].result_bytes == 8 * 9 * 4  # tuple sum halved
    assert ops[0].group_size == 8


# ---------------------------------------------------------------------------
# captured-HLO fixtures (real JAX 0.4.37 output; see file headers)
# ---------------------------------------------------------------------------

def test_fixture_sharded_collectives():
    """The real sharded CA-BCD lowering at iters=4, s=2: exactly H=2
    all-reduces of the fused (sb, sb+1) packet, brace-form replica groups
    over all 8 devices, nothing else on the wire."""
    txt = _fixture("ca_bcd_sharded_jax0437.txt")
    ops = parse_collectives(txt)
    assert [op.kind for op in ops] == ["all-reduce", "all-reduce"]
    for op in ops:
        assert op.group_size == 8, op
        assert op.result_bytes == 8 * 9 * 4, op  # f32[8,9] fused packet
    assert collective_dtypes(txt) == {"f32"}
    # consumer lines that merely REFERENCE %all-reduce.N are not ops
    assert sum("all-reduce" in ln for ln in txt.splitlines()) > 2


def test_fixture_named_ops_ref_panel():
    """The local ref lowering materializes the (sb=8, n=256) sampled panel:
    a gather op plus the fusion XLA names after the gather it absorbed --
    the shapes the contract engine's panel check keys on."""
    txt = _fixture("ca_bcd_local_ref_jax0437.txt")
    assert not parse_collectives(txt)  # local backend: nothing on the wire
    gathers = parse_named_ops(txt, opcodes=("gather",))
    assert len(gathers) == 1 and gathers[0].shapes() == ((8, 256),)
    fusions = [op for op in parse_named_ops(txt, opcodes=("fusion",))
               if "gather" in op.result_name]
    assert fusions and fusions[0].shapes() == ((8, 256),)
    assert gathers[0].dtypes() == ("f32",)


def test_fixture_legacy_dual_transpose():
    """The legacy pre-transpose dual's lowering: the operand-shaped
    transpose ((16, 256) shard -> (256, 16)) the PR-5 contract forbids."""
    txt = _fixture("legacy_dual_pretranspose_jax0437.txt")
    trs = parse_named_ops(txt, opcodes=("transpose",))
    assert len(trs) == 2
    assert all(op.shapes() == ((256, 16),) for op in trs)


def test_real_hlo_psum():
    """End-to-end on real compiled HLO (1-device mesh still emits the op
    structure when contracted over a sharded axis on multi-dev meshes; here we
    just assert the parser tolerates real output)."""
    mesh = compat.make_mesh((1,), ("d",))
    f = jax.jit(lambda x: x @ x.T,
                in_shardings=NamedSharding(mesh, P(None, "d")))
    comp = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    s = collective_summary(comp.as_text())
    assert s.count >= 0  # parser never crashes on real HLO

"""SSD (Mamba-2) kernel-level correctness: the chunked scan vs the naive
token recurrence oracle, chunk-size invariance, decode-step continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st

from repro.models.mamba2 import naive_ssd, ssd_chunked


def _inputs(seed, B=2, L=64, H=4, P=8, N=16):
    ks = jax.random.split(jax.random.key(seed), 4)
    xdt = 0.5 * jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dtA = -jnp.abs(0.1 * jax.random.normal(ks[1], (B, L, H), jnp.float32))
    Bm = 0.5 * jax.random.normal(ks[2], (B, L, N), jnp.float32)
    Cm = 0.5 * jax.random.normal(ks[3], (B, L, N), jnp.float32)
    return xdt, dtA, Bm, Cm


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_chunked_equals_naive(chunk):
    xdt, dtA, Bm, Cm = _inputs(0)
    y_ref, S_ref = naive_ssd(xdt, dtA, Bm, Cm)
    y, S = ssd_chunked(xdt, dtA, Bm, Cm, chunk)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S, S_ref, rtol=2e-4, atol=2e-4)


def test_chunk_invariance():
    xdt, dtA, Bm, Cm = _inputs(1)
    y8, s8 = ssd_chunked(xdt, dtA, Bm, Cm, 8)
    y32, s32 = ssd_chunked(xdt, dtA, Bm, Cm, 32)
    np.testing.assert_allclose(y8, y32, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s8, s32, rtol=2e-4, atol=2e-4)


def test_initial_state_continuity():
    """Splitting a sequence across two calls with carried state == one call."""
    xdt, dtA, Bm, Cm = _inputs(2, L=64)
    y_full, S_full = ssd_chunked(xdt, dtA, Bm, Cm, 16)
    y1, S1 = ssd_chunked(xdt[:, :32], dtA[:, :32], Bm[:, :32], Cm[:, :32], 16)
    y2, S2 = ssd_chunked(xdt[:, 32:], dtA[:, 32:], Bm[:, 32:], Cm[:, 32:], 16,
                         S0=S1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S2, S_full, rtol=2e-4, atol=2e-4)


def test_unroll_invariance():
    """The dry-run cost probe's unrolled scan computes the same values."""
    xdt, dtA, Bm, Cm = _inputs(3)
    y1, s1 = ssd_chunked(xdt, dtA, Bm, Cm, 16, unroll=1)
    y4, s4 = ssd_chunked(xdt, dtA, Bm, Cm, 16, unroll=4)
    np.testing.assert_allclose(y1, y4, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(s1, s4, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 500), L=st.sampled_from([16, 32, 48]),
       chunk=st.sampled_from([8, 16]))
def test_ssd_property(seed, L, chunk):
    xdt, dtA, Bm, Cm = _inputs(seed, L=L)
    y_ref, _ = naive_ssd(xdt, dtA, Bm, Cm)
    y, _ = ssd_chunked(xdt, dtA, Bm, Cm, chunk)
    np.testing.assert_allclose(y, y_ref, rtol=5e-4, atol=5e-4)


def test_decay_bounds():
    """States cannot blow up: dtA <= 0 implies the propagator is <= 1."""
    xdt, dtA, Bm, Cm = _inputs(4, L=128)
    _, S = ssd_chunked(xdt, dtA, Bm, Cm, 16)
    bound = float(jnp.abs(xdt).max() * jnp.abs(Bm).max()) * 128
    assert float(jnp.abs(S).max()) < bound

"""Sampling-mode dispatch: ``shard_balanced`` must actually balance.

Regression for the PR-4 satellite: ``sample_blocks(mode="shard_balanced")``
used to fall back to ``global_uniform`` silently (the old ``_sample_one``
comment admitted it), defeating the load-balance guarantee the mode exists
for (DESIGN.md section 2.6).  Now it dispatches to
``sample_blocks_balanced`` when the shard count is given and raises
otherwise.
"""
import jax
import numpy as np
import pytest

from repro.core import sample_blocks, sample_blocks_balanced


@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_every_shard_contributes_b_over_p(n_shards):
    n_total, b, iters = 64, 8, 12
    idx = np.asarray(sample_blocks(jax.random.key(0), n_total, b, iters,
                                   mode="shard_balanced", n_shards=n_shards))
    assert idx.shape == (iters, b)
    shard_len = n_total // n_shards
    per = b // n_shards
    for it in range(iters):
        owners = idx[it] // shard_len
        counts = np.bincount(owners, minlength=n_shards)
        assert np.all(counts == per), (it, counts)   # perfectly balanced
        assert len(set(idx[it].tolist())) == b       # still no replacement


def test_shard_balanced_dispatch_matches_balanced_entry_point():
    key = jax.random.key(1)
    via_mode = sample_blocks(key, 32, 4, 6, mode="shard_balanced", n_shards=4)
    direct = sample_blocks_balanced(key, 32, 4, 6, n_shards=4)
    assert np.array_equal(np.asarray(via_mode), np.asarray(direct))


def test_shard_balanced_without_shard_count_raises():
    with pytest.raises(ValueError, match="sample_blocks_balanced"):
        sample_blocks(jax.random.key(2), 32, 4, 6, mode="shard_balanced")


def test_n_shards_rejected_for_global_uniform():
    with pytest.raises(ValueError, match="shard_balanced"):
        sample_blocks(jax.random.key(3), 32, 4, 6, n_shards=4)


def test_balanced_divisibility_contract():
    with pytest.raises(ValueError, match="divisible"):
        sample_blocks(jax.random.key(4), 32, 6, 3, mode="shard_balanced",
                      n_shards=4)

"""Divisibility-guarded logical sharding rules (the layer that lets one rule
table serve every arch x mesh combination)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.sharding import make_rules


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh shaped (1, 1): structure-only tests
    dev = jax.devices()[:1]
    import numpy as np
    return compat.device_mesh(np.array(dev).reshape(1, 1), ("data", "model"))


def test_divisible_dim_sharded(mesh):
    rules = make_rules(mesh)
    spec = rules.spec_for((32, 128), ("batch", "mlp"))
    assert spec == P("data", "model")


def test_indivisible_dim_dropped():
    """14 heads on a 16-way model axis -> replicated, recorded in the audit."""
    import numpy as np
    devs = np.array(jax.devices() * 16)[:16].reshape(1, 16)
    mesh16 = compat.device_mesh(devs, ("data", "model"))
    rules = make_rules(mesh16)
    spec = rules.spec_for((896, 14, 64), ("embed", "heads", "head_dim"))
    assert spec == P(None, None, None)
    assert any(d[0] == "heads" for d in rules.dropped)


def test_missing_mesh_axis_ignored(mesh):
    """'pod' is absent on the single-pod mesh; batch falls back to 'data'."""
    rules = make_rules(mesh)
    spec = rules.spec_for((32, 64), ("batch", "seq"))
    assert spec[0] in ("data", ("pod", "data"), ("data",))


def test_no_double_use_of_axis(mesh):
    rules = make_rules(mesh)
    spec = rules.spec_for((64, 64), ("mlp", "mlp"))
    used = [s for s in spec if s is not None]
    assert len(used) <= 1  # 'model' cannot shard two dims of one tensor


def test_fsdp_rules_shard_embed(mesh):
    spec = make_rules(mesh, fsdp=True).spec_for((128, 64), ("embed", "mlp"))
    assert spec == P("data", "model")
    spec2 = make_rules(mesh, fsdp=False).spec_for((128, 64), ("embed", "mlp"))
    assert spec2 == P(None, "model")


def test_overrides(mesh):
    rules = make_rules(mesh, overrides={"cache_seq": ("model",)})
    spec = rules.spec_for((2, 64, 8, 16),
                          ("batch", "cache_seq", "kv_heads", "head_dim"))
    assert spec[1] == "model"

"""MoE routing invariants: gate normalization, capacity-drop accounting,
determinism, aux-loss sanity, and no-drop equivalence to the dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models import init_params
from repro.models.moe import moe_block, moe_specs, _capacity


def _setup(capacity_factor=4.0, top_k=2, experts=4, d=32, f=64):
    cfg = dataclasses.replace(
        get_reduced("phi3_5_moe_42b"), d_model=d, d_ff=f,
        dtype=jnp.float32, param_dtype=jnp.float32,
        moe=MoEConfig(num_experts=experts, top_k=top_k,
                      capacity_factor=capacity_factor))
    params = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, d), jnp.float32)
    return cfg, params, x


def test_no_drop_at_high_capacity():
    cfg, params, x = _setup(capacity_factor=8.0)
    out, m = moe_block(params, x, cfg)
    assert float(m["moe_drop_frac"]) == 0.0
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_dense_oracle_equivalence():
    """With no drops, sort-based dispatch == dense weighted-sum-of-experts."""
    cfg, params, x = _setup(capacity_factor=8.0)
    out, _ = moe_block(params, x, cfg)
    # dense oracle: run every expert on every token, weight by top-k gates
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, sel = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["w1"]))
    g = jnp.einsum("td,edf->tef", xf, params["w3"])
    y_all = jnp.einsum("tef,efd->ted", h * g, params["w2"])  # (T, E, D)
    oracle = jnp.zeros_like(xf)
    for k in range(cfg.moe.top_k):
        oracle = oracle + gate[:, k:k+1] * jnp.take_along_axis(
            y_all, sel[:, k][:, None, None].repeat(xf.shape[1], -1), 1)[:, 0]
    np.testing.assert_allclose(out.reshape(T, -1), oracle, rtol=2e-4, atol=2e-4)


def test_drop_accounting_at_capacity_one():
    cfg, params, x = _setup(capacity_factor=0.25)
    _, m = moe_block(params, x, cfg)
    drop = float(m["moe_drop_frac"])
    assert 0.0 < drop < 1.0


def test_determinism():
    cfg, params, x = _setup()
    o1, _ = moe_block(params, x, cfg)
    o2, _ = moe_block(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_aux_loss_positive_and_balanced_bound():
    cfg, params, x = _setup()
    _, m = moe_block(params, x, cfg)
    aux = float(m["moe_aux_loss"])
    # perfectly balanced router gives exactly aux_weight; skew raises it
    assert aux >= 0.0


def test_capacity_rounding():
    assert _capacity(1024, 2, 16, 1.25) % 8 == 0
    assert _capacity(8, 1, 16, 1.0) == 8  # floor

"""Fault-injection + recovery tests (DESIGN.md section 7).

Local (single-device) cases run in this process in f32: the full
{nan-packet, bitflip, drop-shard} x {primal, dual, proximal} detection
matrix, the guard's bitwise no-op on clean solves, the jittered SPD solve's
rank-deficient regression, the supervised device-loss restart, and the
snapshot-cadence model.  The sharded matrix and the f64 1e-10 elastic-resume
acceptance run in an 8-device subprocess via tests/_fault_checks.py (the
test_analysis.py pattern -- the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.bcd import ca_bcd, objective
from repro.core.bdcd import ca_bdcd
from repro.core.engine import (GUARD_MAGNITUDE, GUARD_NONFINITE,
                               GUARD_SHARD_LOSS, sample_blocks)
from repro.core.proximal import ca_proximal_bcd, elastic_net_objective
from repro.core.subproblem import solve_spd, solve_spd_jittered
from repro.faults import FaultPlan, solve_supervised

_SCRIPT = os.path.join(os.path.dirname(__file__), "_fault_checks.py")
_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))

D, N, B, S, ITERS = 16, 40, 2, 3, 30
LAM = 1e-2


def _problem(dual=False):
    X = jax.random.normal(jax.random.key(0), (D, N), jnp.float32)
    y = jax.random.normal(jax.random.key(1), (N,), jnp.float32)
    dim = N if dual else D
    idx = sample_blocks(jax.random.key(2), dim, B, ITERS)
    return X, y, idx


SOLVERS = {
    "primal": (ca_bcd, False, lambda X, w, y: objective(X, w, y, LAM)),
    "dual": (ca_bdcd, True, lambda X, w, y: objective(X, w, y, LAM)),
    "proximal": (ca_proximal_bcd, False,
                 lambda X, w, y: elastic_net_objective(X, w, y, LAM, 1e-3)),
}

# bitflip/divergence guards arm off a clean first step, so inject at >= 1.
KIND_STEP_REASON = [("nan_packet", 2, GUARD_NONFINITE),
                    ("bitflip", 1, GUARD_MAGNITUDE),
                    ("drop_shard", 2, GUARD_SHARD_LOSS)]


@pytest.mark.parametrize("form", sorted(SOLVERS))
@pytest.mark.parametrize("kind,step,reason",
                         KIND_STEP_REASON, ids=lambda v: str(v))
def test_local_fault_detected_and_converges(form, kind, step, reason):
    """Every in-scan fault kind x formulation: the guard trips AT the
    injected outer step with the right reason bit, and the degraded solve
    (skip/rescue + s=1 tail) still converges to the clean objective."""
    solve, dual, obj = SOLVERS[form]
    X, y, idx = _problem(dual)
    kw = {"lam1": 1e-3} if form == "proximal" else {}
    clean = solve(X, y, LAM, B, S, ITERS, None, idx=idx, **kw)
    res = solve(X, y, LAM, B, S, ITERS, None, idx=idx, guard=True,
                fault=FaultPlan(kind, step=step), **kw)
    m = {k: np.asarray(jax.device_get(v)).item()
         for k, v in res.metrics.items()}
    assert m["guard_trips"] >= 1, m
    assert m["guard_first_trip"] == step, m
    assert int(m["guard_first_reason"]) & reason, m
    # rung two engaged: the remaining iterations ran at s=1
    assert m["s1_tail_from_outer"] == step, m
    assert m["s1_tail_from_iter"] == step * S, m
    # Converged near the clean solve: the fault cost at most one outer step
    # of progress (skip) plus the tail's ordering rounding -- NOT a blowup.
    # (Absolute optimality is the clean solver tests' business; the dual in
    # particular converges slowly at this tiny problem scale.)
    o_clean = float(obj(X, clean.w, y))
    o_fault = float(obj(X, res.w, y))
    assert np.isfinite(o_fault)
    assert o_fault <= o_clean * 1.25 + 1e-6, (o_fault, o_clean)


@pytest.mark.parametrize("form", sorted(SOLVERS))
def test_guard_is_bitwise_noop_on_clean_solves(form):
    """Arming the guard on a healthy solve changes NOTHING: same iterates
    bit-for-bit, zero trips -- detection is free until something breaks."""
    solve, dual, _ = SOLVERS[form]
    X, y, idx = _problem(dual)
    kw = {"lam1": 1e-3} if form == "proximal" else {}
    plain = solve(X, y, LAM, B, S, ITERS, None, idx=idx, **kw)
    guarded = solve(X, y, LAM, B, S, ITERS, None, idx=idx, guard=True, **kw)
    np.testing.assert_array_equal(np.asarray(plain.w), np.asarray(guarded.w))
    np.testing.assert_array_equal(np.asarray(plain.alpha),
                                  np.asarray(guarded.alpha))
    m = {k: np.asarray(v).item() for k, v in guarded.metrics.items()}
    assert m["guard_trips"] == 0 and m["guard_first_trip"] == -1, m


# ---------------------------------------------------------------------------
# satellite: NaN-free SPD solve for singular blocks
# ---------------------------------------------------------------------------

def test_solve_spd_jittered_rank_deficient_block():
    """A duplicate-index block at lam=0 makes the sb x sb matrix exactly
    singular: plain solve_spd emits NaN (the pre-PR-7 breakage), the
    jittered ladder returns a finite solution and flags the jitter."""
    s, b = 4, 2
    X, _, _ = _problem()
    flat = jnp.array([3, 3, 3, 3, 5, 5, 5, 5])    # rank-2 Gram, sb=8
    Y = X[flat, :]
    A = Y @ Y.T / N                               # lam = 0: singular
    rhs = jnp.ones((s * b,), jnp.float32)
    assert not bool(jnp.all(jnp.isfinite(solve_spd(A, rhs))))
    x, jitter, ok = solve_spd_jittered(A, rhs)
    assert bool(jnp.all(jnp.isfinite(x)))
    assert bool(ok)
    assert float(jitter) > 0


def test_guarded_solve_survives_duplicate_indices_at_lam0():
    """End-to-end regression: the same rank-deficient duplicate-index stream
    at lam=0, s=4 NaNs the unguarded CA solve; the guard rescues it."""
    X, y, _ = _problem()
    idx = jnp.tile(jnp.array([[3, 3], [5, 5]], jnp.int32), (6, 1))  # 12 iters
    bad = ca_bcd(X, y, 0.0, B, 4, 12, None, idx=idx)
    assert not bool(jnp.all(jnp.isfinite(bad.w)))
    res = ca_bcd(X, y, 0.0, B, 4, 12, None, idx=idx, guard=True)
    assert bool(jnp.all(jnp.isfinite(res.w)))
    m = {k: np.asarray(v).item() for k, v in res.metrics.items()}
    assert m["guard_trips"] >= 1, m
    assert float(objective(X, res.w, y, 0.0)) < float(
        objective(X, jnp.zeros_like(res.w), y, 0.0))


# ---------------------------------------------------------------------------
# supervised solves (local backend; the sharded/elastic path is subprocess)
# ---------------------------------------------------------------------------

def test_supervised_local_device_loss_resumes(tmp_path):
    """Device loss mid-solve: the supervisor restores the newest snapshot
    and the finished solve matches the uninterrupted one."""
    X, y, idx = _problem()
    fault = FaultPlan("device_loss", step=4)
    res = solve_supervised("primal", "local", X, y, LAM, B, S, ITERS, None,
                           idx=idx, ckpt_dir=str(tmp_path), fault=fault)
    assert res.metrics["restarts"] == 1
    assert res.metrics["resumed_from_iter"] > 0
    clean = ca_bcd(X, y, LAM, B, S, ITERS, None, idx=idx)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(clean.w),
                               rtol=0, atol=1e-5)


def test_supervised_restart_budget_exhausted(tmp_path):
    """A loss injected at step 0 with max_restarts=0 must surface, not loop."""
    from repro.faults import DeviceLostError
    X, y, idx = _problem()
    with pytest.raises(DeviceLostError):
        solve_supervised("primal", "local", X, y, LAM, B, S, ITERS, None,
                         idx=idx, ckpt_dir=str(tmp_path), max_restarts=0,
                         fault=FaultPlan("device_loss", step=0))


def test_snapshot_cadence_model():
    from repro.core.cost_model import TPU_V5E_ICI, snapshot_cadence
    out = snapshot_cadence(TPU_V5E_ICI, d=1 << 16, n=1 << 20, P=64, b=8,
                           s=16, mtbf_outer=1e6)
    assert out["cadence"] >= 1
    assert 0 < out["overhead"] < 1
    # rarer failures -> snapshot less often
    rare = snapshot_cadence(TPU_V5E_ICI, d=1 << 16, n=1 << 20, P=64, b=8,
                            s=16, mtbf_outer=1e8)
    assert rare["cadence"] > out["cadence"]
    with pytest.raises(ValueError):
        snapshot_cadence(TPU_V5E_ICI, d=4, n=8, P=1, b=1, s=1, mtbf_outer=0)


# ---------------------------------------------------------------------------
# FaultPlan validation
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan("meteor_strike", step=0)
    with pytest.raises(ValueError):
        FaultPlan("nan_packet", step=-1)
    with pytest.raises(ValueError):
        engine.SolverPlan(b=2, s=2, fault=object())   # duck-type check
    with pytest.raises(ValueError):
        engine.SolverPlan(b=2, s=2, guard_boost=1.0)


# ---------------------------------------------------------------------------
# sharded matrix + f64 elastic resume: 8-device subprocess checks
# ---------------------------------------------------------------------------

def _run(check: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(_SRC) + os.pathsep + \
        os.path.dirname(__file__) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, _SCRIPT, check], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=_ROOT)
    assert proc.returncode == 0, (
        f"{check} failed:\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert f"{check} OK" in proc.stdout


def test_sharded_fault_matrix():
    """{nan, bitflip, drop-shard} x {primal, dual, proximal} on an 8-device
    mesh: detected at the injected step, converged objective."""
    _run("fault_matrix_sharded")


def test_pipelined_fault_parity():
    """Guard trips under the pipelined ring wire degrade identically to the
    psum backend: same reason bits, same trip step, same s=1 tail."""
    _run("fault_parity_pipelined")


def test_supervised_elastic_resume_sharded():
    """The acceptance gate: injected device loss, resume on a smaller mesh
    from the newest snapshot, f64 objective matches the uninterrupted solve
    to 1e-10 on even AND ragged schedules."""
    _run("supervised_resume_sharded")


def test_supervised_resume_local_f64():
    _run("supervised_resume_local")

"""Per-architecture smoke tests (assignment requirement): every assigned arch
instantiates a REDUCED same-family config and runs one forward + one train
step on CPU, asserting output shapes and finiteness.  Full configs are only
ever lowered abstractly (launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, SHAPES, get_config, get_reduced,
                           n_active_params, n_params)
from repro.data import synthetic_lm_batch
from repro.models import api, init_params
from repro.optim import AdamWConfig
from repro.train import make_train_step
from repro.optim import init_opt_state


def _batch_for(cfg, B=2, S=64, key=0):
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_lm_batch(cfg.vocab, S, B, seed=key).items()}
    if cfg.family == "audio":
        batch["src_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(key + 1), (B, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["extra_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(key + 1), (B, cfg.frontend_tokens, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    B, S = 2, 64
    batch = _batch_for(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: api.forward(p, cfg, b))(params, batch)
    S_total = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


# jamba's reduced config is by far the slowest train step on CPU (~55s); the
# PR gate runs `-m "not slow"`, the full tier-1 suite still covers it.
_TRAIN_ARCHS = [pytest.param(a, marks=pytest.mark.slow)
                if a == "jamba_1_5_large_398b" else a for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _TRAIN_ARCHS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    batch = _batch_for(cfg, 2, 64)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda p, q: bool(jnp.any(p != q)),
                     state["params"], new_state["params"]))
    assert moved


def test_param_counts_match_published():
    """Full-config analytic parameter counts vs published totals (+-6%)."""
    expected = {
        "llama3.2-3b": 3.2e9, "mistral-nemo-12b": 12.2e9,
        "qwen2-0.5b": 0.49e9, "granite-3-2b": 2.53e9,
        "mamba2-370m": 0.37e9, "seamless-m4t-large-v2": 2.0e9,
        "jamba-1.5-large-398b": 398e9, "dbrx-132b": 132e9,
        "phi3.5-moe-42b": 42e9, "llava-next-34b": 34e9,
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        got = n_params(cfg)
        want = expected[cfg.name]
        assert abs(got - want) / want < 0.06, (cfg.name, got, want)


def test_active_params_moe():
    assert abs(n_active_params(get_config("phi3_5_moe_42b")) - 6.6e9) / 6.6e9 < 0.06
    assert abs(n_active_params(get_config("jamba_1_5_large_398b")) - 94e9) / 94e9 < 0.06


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    runs = {a: long.applicable(get_config(a))[0] for a in ARCH_IDS}
    assert runs["mamba2_370m"] and runs["jamba_1_5_large_398b"]
    assert sum(runs.values()) == 2  # all full-attention archs skip


def test_hybrid_interleave():
    cfg = get_config("jamba_1_5_large_398b")
    kinds = [cfg.layer_kind(i) for i in range(8)]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    mlps = [cfg.mlp_kind(i) for i in range(8)]
    assert mlps.count("moe") == 4  # every other layer


def test_vocab_padding():
    cfg = get_config("granite_3_2b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab % 16 == 0  # TP-16 clean


def test_loss_ignores_vocab_padding():
    """Labels never hit padded vocab rows; loss is finite and gradient of the
    pad rows of the embedding stays zero for tied models."""
    cfg = dataclasses.replace(get_reduced("granite_3_2b"),
                              dtype=jnp.float32, param_dtype=jnp.float32)
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    batch = _batch_for(cfg, 2, 32)
    (loss, _), grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))

"""Gram-backend dispatch layer: solver-level equivalence of ``impl="ref"``
vs ``impl="pallas_interpret"`` for all four solvers (float64), plus the
pad/unpad path for non-tile-aligned sb and the fused-diagonal reg path.

This is the wiring test for the tentpole: the solvers build every Gram +
residual pair through ``repro.core.gram_packet``, so forcing the kernel
backend end-to-end must reproduce the reference iterates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bcd, bdcd, ca_bcd, ca_bdcd, gram_packet,
                        sample_blocks)
from repro.data import SyntheticSpec, make_regression
from repro.kernels.gram import gram_packet_ref

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3
ITERS = 12


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=24, n=80, cond=1e4))
    return X, y


def _assert_same_iterates(r_ref, r_pi):
    np.testing.assert_allclose(r_pi.w, r_ref.w, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.alpha, r_ref.alpha, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.history["objective"],
                               r_ref.history["objective"], rtol=1e-10, atol=0)


def test_bcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(1), X.shape[0], 4, ITERS)
    r_ref = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_pi = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_bcd_impl_equivalence(problem):
    """sb = 3*4 = 12 is not a multiple of the 8-row kernel tile: this case
    runs the pad/unpad path in kernels/gram/ops.py on every outer step."""
    X, y = problem
    idx = sample_blocks(jax.random.key(2), X.shape[0], 4, ITERS)
    r_ref = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                  impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_bdcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(3), X.shape[1], 4, ITERS)
    r_ref = bdcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_pi = bdcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_bdcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(4), X.shape[1], 4, ITERS)
    r_ref = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                   impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_impl_preserves_classical_equivalence(problem):
    """The paper's exact-equivalence claim survives the backend swap: CA(s)
    under pallas_interpret still reproduces classical BCD under ref."""
    X, y = problem
    idx = sample_blocks(jax.random.key(5), X.shape[0], 4, ITERS)
    r_cl = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_ca = ca_bcd(X, y, LAM, 4, 4, ITERS, None, idx=idx,
                  impl="pallas_interpret")
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-10, atol=1e-11)


def test_packet_non_tile_aligned_f64():
    """Direct packet check on a ragged (m, n): pad rows to the 8-multiple,
    pad columns to the 128-multiple, slice back -- exact in f64."""
    m, n = 13, 70  # m % 8 != 0, n % 128 != 0
    A = jax.random.normal(jax.random.key(6), (m, n), jnp.float64)
    u = jax.random.normal(jax.random.key(7), (n,), jnp.float64)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.5, scale_r=2.0,
                         impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.5, 2.0)
    assert G1.shape == (m, m) and r1.shape == (m,)
    np.testing.assert_allclose(G1, G0, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(r1, r0, rtol=1e-12, atol=1e-12)


def test_packet_reg_and_scale_r_semantics():
    """The dispatch-layer contract the solvers rely on:
    G = scale*A A^T + reg*I (fused diagonal), r = scale_r * A u."""
    m, n = 6, 40
    A = jax.random.normal(jax.random.key(8), (m, n), jnp.float64)
    u = jax.random.normal(jax.random.key(9), (n,), jnp.float64)
    for impl in ("ref", "pallas_interpret"):
        G, r = gram_packet(A, u, scale=0.25, reg=1.5, scale_r=3.0, impl=impl)
        np.testing.assert_allclose(
            G, 0.25 * A @ A.T + 1.5 * jnp.eye(m), rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(r, 3.0 * A @ u, rtol=1e-12, atol=1e-12)


def test_unknown_impl_rejected():
    A = jnp.ones((4, 8))
    with pytest.raises(ValueError, match="unknown gram impl"):
        gram_packet(A, jnp.ones((8,)), impl="cuda")

"""Gram-backend dispatch layer: solver-level equivalence of ``impl="ref"``
vs ``impl="pallas_interpret"`` for all four solvers (float64), plus the
pad/unpad path for non-tile-aligned sb and the fused-diagonal reg path.

This is the wiring test for the tentpole: the solvers build every Gram +
residual pair through the dispatch layer -- panel-free via
``gram_packet_sampled`` + ``panel_apply`` since PR 2, so the solver-level
cases below exercise the index-prefetched gather kernel end-to-end (including
duplicate indices inside an outer block and non-tile-aligned sb/n pad/unpad),
and forcing the kernel backend must reproduce the reference iterates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bcd, bdcd, ca_bcd, ca_bdcd, cg_ridge, cholqr_r,
                        gram_packet, gram_packet_sampled, normal_matvec,
                        panel_apply, panel_matvec, ridge_exact, sample_blocks,
                        tsqr_ridge)
from repro.data import SyntheticSpec, make_regression
from repro.kernels.gram import gram_packet_ref, gram_packet_sampled_ref

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3
ITERS = 12


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=24, n=80, cond=1e4))
    return X, y


def _assert_same_iterates(r_ref, r_pi):
    np.testing.assert_allclose(r_pi.w, r_ref.w, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.alpha, r_ref.alpha, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_pi.history["objective"],
                               r_ref.history["objective"], rtol=1e-10, atol=0)


def test_bcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(1), X.shape[0], 4, ITERS)
    r_ref = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_pi = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_bcd_impl_equivalence(problem):
    """sb = 3*4 = 12 is not a multiple of the 8-row kernel tile: this case
    runs the pad/unpad path in kernels/gram/ops.py on every outer step."""
    X, y = problem
    idx = sample_blocks(jax.random.key(2), X.shape[0], 4, ITERS)
    r_ref = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                  impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_bdcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(3), X.shape[1], 4, ITERS)
    r_ref = bdcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_pi = bdcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_bdcd_impl_equivalence(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(4), X.shape[1], 4, ITERS)
    r_ref = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                   impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_impl_preserves_classical_equivalence(problem):
    """The paper's exact-equivalence claim survives the backend swap: CA(s)
    under pallas_interpret still reproduces classical BCD under ref."""
    X, y = problem
    idx = sample_blocks(jax.random.key(5), X.shape[0], 4, ITERS)
    r_cl = bcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    r_ca = ca_bcd(X, y, LAM, 4, 4, ITERS, None, idx=idx,
                  impl="pallas_interpret")
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-10, atol=1e-11)


def test_packet_non_tile_aligned_f64():
    """Direct packet check on a ragged (m, n): pad rows to the 8-multiple,
    pad columns to the 128-multiple, slice back -- exact in f64."""
    m, n = 13, 70  # m % 8 != 0, n % 128 != 0
    A = jax.random.normal(jax.random.key(6), (m, n), jnp.float64)
    u = jax.random.normal(jax.random.key(7), (n,), jnp.float64)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.5, scale_r=2.0,
                         impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.5, 2.0)
    assert G1.shape == (m, m) and r1.shape == (m,)
    np.testing.assert_allclose(G1, G0, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(r1, r0, rtol=1e-12, atol=1e-12)


def test_packet_reg_and_scale_r_semantics():
    """The dispatch-layer contract the solvers rely on:
    G = scale*A A^T + reg*I (fused diagonal), r = scale_r * A u."""
    m, n = 6, 40
    A = jax.random.normal(jax.random.key(8), (m, n), jnp.float64)
    u = jax.random.normal(jax.random.key(9), (n,), jnp.float64)
    for impl in ("ref", "pallas_interpret"):
        G, r = gram_packet(A, u, scale=0.25, reg=1.5, scale_r=3.0, impl=impl)
        np.testing.assert_allclose(
            G, 0.25 * A @ A.T + 1.5 * jnp.eye(m), rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(r, 3.0 * A @ u, rtol=1e-12, atol=1e-12)


def test_unknown_impl_rejected():
    A = jnp.ones((4, 8))
    with pytest.raises(ValueError, match="unknown gram impl"):
        gram_packet(A, jnp.ones((8,)), impl="cuda")
    with pytest.raises(ValueError, match="unknown gram impl"):
        gram_packet_sampled(A, jnp.zeros((2,), jnp.int32), jnp.ones((8,)),
                            impl="cuda")
    with pytest.raises(ValueError, match="unknown gram impl"):
        panel_apply(A, jnp.zeros((2,), jnp.int32), jnp.ones((2,)), impl="cuda")


# --------------------------------------------------------------------------
# Panel-free sampled path (PR 2): solver-level with duplicate indices, plus
# direct checks of the index-prefetched kernel's pad/unpad and gather.
# --------------------------------------------------------------------------

def _dup_idx(key, n_total, b, iters):
    """Index stream whose second inner block repeats the first, so every CA
    outer block's flat carries exact duplicates (the overlap-matrix path) and
    the sampled kernel must gather the same rows twice."""
    idx = sample_blocks(key, n_total, b, iters)
    return idx.at[1::2].set(idx[0::2])


def test_ca_bcd_sampled_duplicate_indices(problem):
    X, y = problem
    idx = _dup_idx(jax.random.key(10), X.shape[0], 4, ITERS)
    r_ref = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                  impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_ca_bdcd_sampled_duplicate_indices(problem):
    X, y = problem
    idx = _dup_idx(jax.random.key(11), X.shape[1], 4, ITERS)
    r_ref = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx, impl="ref")
    r_pi = ca_bdcd(X, y, LAM, 4, 3, ITERS, None, idx=idx,
                   impl="pallas_interpret")
    _assert_same_iterates(r_ref, r_pi)


def test_sampled_packet_non_tile_aligned_f64():
    """Direct sampled-packet check on ragged (m, n): flat padded to the 8-row
    tile, X columns padded to the 128 lane tile, sliced back -- exact in f64,
    with duplicate and repeated-0 indices in flat."""
    d, n = 23, 70  # n % 128 != 0
    X = jax.random.normal(jax.random.key(12), (d, n), jnp.float64)
    u = jax.random.normal(jax.random.key(13), (n,), jnp.float64)
    flat = jnp.asarray([5, 5, 0, 22, 7, 7, 7, 1, 0, 19, 3, 2, 11],
                       jnp.int32)  # m=13, m % 8 != 0
    G1, r1 = gram_packet_sampled(X, flat, u, scale=1.0 / n, reg=0.5,
                                 scale_r=2.0, impl="pallas_interpret")
    G0, r0 = gram_packet_sampled_ref(X, flat, u, 1.0 / n, 0.5, 2.0)
    assert G1.shape == (13, 13) and r1.shape == (13,)
    np.testing.assert_allclose(G1, G0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r1, r0, rtol=0, atol=1e-10)
    # and against the materialized-panel packet: same numbers, no panel
    G2, r2 = gram_packet(X[flat, :], u, scale=1.0 / n, reg=0.5, scale_r=2.0,
                         impl="pallas_interpret")
    np.testing.assert_allclose(G1, G2, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r1, r2, rtol=0, atol=1e-10)


def test_panel_apply_matches_ref():
    d, n = 31, 200
    X = jax.random.normal(jax.random.key(14), (d, n), jnp.float64)
    flat = jnp.asarray([3, 3, 0, 30, 8], jnp.int32)
    v = jax.random.normal(jax.random.key(15), (5,), jnp.float64)
    a0 = 0.7 * X[flat, :].T @ v
    for impl in ("ref", "pallas_interpret"):
        a1 = panel_apply(X, flat, v, scale=0.7, impl=impl)
        np.testing.assert_allclose(a1, a0, rtol=0, atol=1e-10)


def test_panel_matvec_matches_ref():
    d, n = 31, 200
    X = jax.random.normal(jax.random.key(16), (d, n), jnp.float64)
    flat = jnp.asarray([3, 3, 0, 30, 8], jnp.int32)
    t = jax.random.normal(jax.random.key(17), (n,), jnp.float64)
    m0 = 1.3 * X[flat, :] @ t
    for impl in ("ref", "pallas_interpret"):
        m1 = panel_matvec(X, flat, t, scale=1.3, impl=impl)
        np.testing.assert_allclose(m1, m0, rtol=0, atol=1e-10)


# --------------------------------------------------------------------------
# Remaining Gram-shaped hot spots routed through the dispatch layer
# --------------------------------------------------------------------------

def test_normal_matvec_impls_agree(problem):
    X, _ = problem
    d, n = X.shape
    v = jax.random.normal(jax.random.key(18), (d,), jnp.float64)
    ref = X @ (X.T @ v) / n + LAM * v
    for impl in ("ref", "pallas_interpret"):
        out = normal_matvec(X, v, lam=LAM, scale=1.0 / n, impl=impl)
        np.testing.assert_allclose(out, ref, rtol=1e-12, atol=1e-12)


def test_cg_ridge_kernel_backend(problem):
    """CG with the normal-equations products on the kernel backend converges
    to the same ridge solution (the krylov routing satellite)."""
    X, y = problem
    w_opt = ridge_exact(X, y, LAM)
    w = cg_ridge(X, y, LAM, tol=1e-14, max_iters=500,
                 impl="pallas_interpret").w
    np.testing.assert_allclose(w, w_opt, rtol=1e-9, atol=1e-11)


def test_tsqr_ridge_cholqr_gram_routed(problem):
    """CholeskyQR path: the R-factor Gram built by the dispatch layer gives
    the same ridge solution as Householder TSQR (both dual and primal
    branches)."""
    X, y = problem
    w_opt = ridge_exact(X, y, LAM)
    for impl in ("ref", "pallas_interpret"):
        w = tsqr_ridge(X, y, LAM, method="cholqr", impl=impl)
        np.testing.assert_allclose(w, w_opt, rtol=1e-8, atol=1e-10)
    Xt = X.T
    yt = jnp.ones((X.shape[0],), X.dtype)
    w2 = tsqr_ridge(Xt, yt, LAM, method="cholqr", impl="pallas_interpret")
    np.testing.assert_allclose(w2, ridge_exact(Xt, yt, LAM), rtol=1e-8,
                               atol=1e-10)


def test_cholqr_r_factor(problem):
    X, _ = problem
    A = jnp.concatenate([X.T, jnp.eye(X.shape[0], dtype=X.dtype)], axis=0)
    for impl in ("ref", "pallas_interpret"):
        R = cholqr_r(A, impl=impl)
        np.testing.assert_allclose(R.T @ R, A.T @ A, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(R, jnp.triu(R), rtol=0, atol=0)

"""End-to-end behaviour tests for the paper's system: the full story --
sample, solve, communicate every s iterations, converge identically --
exercised through the public API exactly as examples/quickstart.py uses it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcd, ca_bcd, ridge_exact, sample_blocks
from repro.data import PAPER_DATASETS, SyntheticSpec, make_regression

from _x64 import x64_mode  # noqa: F401


def test_end_to_end_paper_story():
    """The quickstart scenario: CA-BCD converges to the ridge solution along
    the identical trajectory as BCD while communicating 1/s as often."""
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("sys", d=96, n=384, cond=1e8))
    lam = 1e-2
    w_opt = ridge_exact(X, y, lam)
    iters, b, s = 400, 8, 20
    idx = sample_blocks(jax.random.key(1), 96, b, iters)
    r_cl = bcd(X, y, lam, b, iters, None, idx=idx, w_ref=w_opt)
    r_ca = ca_bcd(X, y, lam, b, s, iters, None, idx=idx, w_ref=w_opt)
    # identical trajectory ...
    np.testing.assert_allclose(r_ca.history["objective"],
                               r_cl.history["objective"], rtol=1e-9)
    # ... that actually converges
    assert float(r_ca.history["sol_err"][-1]) < 1e-4


# The two largest stand-ins dominate the suite's wall clock (~60s combined on
# CPU); the PR gate runs `-m "not slow"`, the full tier-1 suite covers them.
_DATASETS = [pytest.param(n, marks=pytest.mark.slow)
             if n in ("real-sim", "news20") else n for n in PAPER_DATASETS]


@pytest.mark.parametrize("name", _DATASETS)
def test_paper_dataset_standins_solvable(name):
    """Table 3 stand-ins: generated at the right shape/conditioning and the
    solver stack makes progress on each."""
    spec = PAPER_DATASETS[name]
    X, y, _ = make_regression(jax.random.key(7), spec)
    assert X.shape == (spec.d, spec.n)
    lam = 1e-3 * float(jnp.linalg.norm(X) ** 2 / min(X.shape))
    w_opt = ridge_exact(X, y, lam)
    b = min(8, spec.d)
    res = ca_bcd(X, y, lam, b=b, s=5, iters=50, key=jax.random.key(8),
                 w_ref=w_opt)
    errs = res.history["sol_err"]
    # converged (d <= b solves exactly in one iteration) or descending
    assert float(errs[-1]) < 1e-6 or float(errs[-1]) < float(errs[0])
    assert np.all(np.isfinite(np.asarray(errs)))


def test_conditioning_of_standins():
    spec = PAPER_DATASETS["abalone"]
    X, _, _ = make_regression(jax.random.key(9), spec)
    G = X @ X.T if spec.d <= spec.n else X.T @ X
    evs = np.linalg.eigvalsh(np.asarray(G))
    cond = evs[-1] / max(evs[0], 1e-300)
    assert 0.01 * spec.cond < cond < 100 * spec.cond

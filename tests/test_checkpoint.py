"""Fault-tolerance tests: atomic save/restore roundtrip, CRC corruption
fallback, keep-k pruning, async writer, data-state resume, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.float32),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def _like(state):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state(3)
    mgr.save(3, state, {"data": {"step": 3}})
    restored, extra, step = mgr.restore_latest(_like(state))
    assert step == 3 and extra["data"]["step"] == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), state, restored)


def test_bf16_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": jnp.full((4,), 1.5, jnp.bfloat16)}
    mgr.save(1, state)
    restored, _, _ = mgr.restore_latest(_like(state))
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.full((4,), 1.5, np.float32))


def test_corruption_falls_back_to_older(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep=5)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    # corrupt the newest checkpoint's first leaf
    d = os.path.join(str(tmp_path), "step_0000000002")
    leaf = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, leaf), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xde\xad\xbe\xef")
    restored, _, step = mgr.restore_latest(_like(_state(0)))
    assert step == 1  # fell back
    assert int(restored["step"]) == 1


def test_keep_k_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _state(7))
    mgr.wait()
    assert mgr.all_steps() == [7]


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(1))
    assert not [f for f in os.listdir(str(tmp_path)) if f.endswith(".tmp")]
    assert open(os.path.join(str(tmp_path), "LATEST")).read() == "step_0000000001"


def _break_directory(path):
    """Replace the checkpoint directory with a regular file so every write
    inside it fails (works under root, unlike permission bits)."""
    import shutil
    shutil.rmtree(path)
    with open(path, "w") as f:
        f.write("not a directory")


def test_async_writer_error_surfaces_on_next_save(tmp_path):
    """A failed background write must NOT vanish with the thread: the next
    save() re-raises it as CheckpointWriteError (chained to the original)."""
    from repro.checkpoint import CheckpointWriteError
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, async_save=True)
    mgr.save(1, _state(1))
    mgr.wait()                       # clean write goes through
    assert mgr.all_steps() == [1]
    _break_directory(d)
    mgr.save(2, _state(2))           # writer thread dies silently...
    with pytest.raises(CheckpointWriteError) as exc:
        mgr.save(3, _state(3))       # ...and THIS surfaces it
    assert exc.value.__cause__ is not None


def test_async_writer_error_surfaces_on_close(tmp_path):
    from repro.checkpoint import CheckpointWriteError
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, async_save=True)
    _break_directory(d)
    mgr.save(1, _state(1))
    with pytest.raises(CheckpointWriteError):
        mgr.close()
    mgr.close()                      # error is consumed; close is idempotent


def test_sync_save_raises_immediately(tmp_path):
    from repro.checkpoint import CheckpointWriteError
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, async_save=False)
    _break_directory(d)
    with pytest.raises(CheckpointWriteError):
        mgr.save(1, _state(1))


def test_structure_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(1))
    bad_like = {"other": jax.ShapeDtypeStruct((3,), jnp.float32)}
    assert mgr.restore_latest(bad_like) is None


def test_data_stream_exact_resume():
    s1 = TokenStream(vocab=100, seq_len=16, global_batch=4, seed=9)
    for _ in range(5):
        next(s1)
    saved = s1.state_dict()
    b6 = next(s1)
    s2 = TokenStream(vocab=100, seq_len=16, global_batch=4, seed=9)
    s2.load_state_dict(saved)
    b6r = next(s2)
    np.testing.assert_array_equal(b6["tokens"], b6r["tokens"])


def test_host_sharding_disjoint_union():
    """Per-host streams partition the global batch deterministically."""
    h0 = TokenStream(vocab=50, seq_len=8, global_batch=4, seed=1,
                     host_index=0, num_hosts=2)
    h1 = TokenStream(vocab=50, seq_len=8, global_batch=4, seed=1,
                     host_index=1, num_hosts=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 2
    # different hosts produce different (independent-stream) data
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])
    # determinism per host
    np.testing.assert_array_equal(h0.batch_at(3)["tokens"],
                                  h0.batch_at(3)["tokens"])

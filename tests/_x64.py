"""Module-scoped x64 toggle for solver-exactness tests."""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def x64_mode():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)

"""Batched multi-tenant engine: T-tenant solves == T independent solves.

DESIGN.md section 8's equivalence claim, pinned as tests:

* bit-for-bit on ``pallas_interpret`` with pinned kernel tiles (the regime
  where the shared RAW packet + per-tenant ``_assemble_subproblem`` keeps
  both drivers' expression graphs -- and their LLVM fma contraction --
  identical), even and ragged iteration counts, mixed per-tenant lam, and
  per-tenant proximal ``lam1`` coefficients;
* <= 1e-12 relative on the f64 ref backend;
* a retired-early tenant's carry is FROZEN (masked updates are exact no-ops)
  while its neighbors keep matching their single solves bit-for-bit;
* the continuous-batching front end (``serve.solver_service``) lands every
  request on the single-solve answer through admits/chunks/retirement.

Bitwise tests pin ``tiles`` explicitly: the equivalence holds per kernel
launch geometry, and autotuned picks may differ across hosts.  Proximal
tenants use ``lam1 > 0`` everywhere -- at traced ``lam1 = 0`` the prox path
is not the ridge branch the single driver statically selects (documented
contract on ``_BoundProximal``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _x64 import x64_mode  # noqa: F401  (autouse fixture)
from repro.core import (ProximalElasticNet, SolverPlan, TenantBatch,
                        ridge_exact, s_step_solve, s_step_solve_batched,
                        sample_blocks)
from repro.core.engine import _resolve_form

D, N, T, B, S = 24, 40, 3, 4, 3
LAMS = (0.1, 0.5, 1.0)          # mixed per-tenant l2 weights
LAM1S = (0.02, 0.01, 0.05)      # per-tenant proximal l1 weights (> 0)


def _problem(dtype):
    kX, kY = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(kX, (D, N), dtype)
    ys = jax.random.normal(kY, (T, N), dtype)
    return X, ys


def _single(form_name, t, plan, X, ys, iters, idx):
    f = ProximalElasticNet(lam1=LAM1S[t]) if form_name == "proximal" \
        else form_name
    return s_step_solve(f, plan, X, ys[t], LAMS[t], iters, idx=idx)


def _batch(form_name, X, ys, tol=None):
    coeffs = {}
    if form_name == "proximal":
        coeffs = {"lam1": jnp.asarray(LAM1S, ys.dtype)}
    return TenantBatch(ys=ys, lams=jnp.asarray(LAMS, ys.dtype),
                       coeffs=coeffs, tol=tol)


@pytest.mark.parametrize("form_name", ["primal", "dual", "proximal"])
@pytest.mark.parametrize("iters", [6, 5])       # 6 = 2 full steps, 5 = ragged
def test_batched_matches_singles_bitwise(form_name, iters):
    """One scan, one packet, T tenants -- every iterate equal under ``==``
    to its independent single solve on the interpret kernel backend."""
    X, ys = _problem(jnp.float32)
    plan = SolverPlan(b=B, s=S, impl="pallas_interpret", tiles=(8, 256))
    form = _resolve_form(form_name)
    idx = sample_blocks(jax.random.PRNGKey(7), form.sample_dim(D, N), B,
                        iters)
    res = s_step_solve_batched(form_name, plan, X, _batch(form_name, X, ys),
                               iters, idx=idx)
    for t in range(T):
        r = _single(form_name, t, plan, X, ys, iters, idx)
        np.testing.assert_array_equal(np.asarray(res.ws[t]), np.asarray(r.w))
        np.testing.assert_array_equal(np.asarray(res.alphas[t]),
                                      np.asarray(r.alpha))


@pytest.mark.parametrize("form_name", ["primal", "dual", "proximal"])
def test_batched_matches_singles_ref_f64(form_name):
    """f64 ref backend: <= 1e-12 relative against the T single solves
    (ragged iteration count, mixed lams)."""
    X, ys = _problem(jnp.float64)
    plan = SolverPlan(b=B, s=S, impl="ref")
    form = _resolve_form(form_name)
    iters = 7
    idx = sample_blocks(jax.random.PRNGKey(9), form.sample_dim(D, N), B,
                        iters)
    res = s_step_solve_batched(form_name, plan, X, _batch(form_name, X, ys),
                               iters, idx=idx)
    for t in range(T):
        r = _single(form_name, t, plan, X, ys, iters, idx)
        np.testing.assert_allclose(np.asarray(res.ws[t]), np.asarray(r.w),
                                   rtol=1e-12, atol=1e-14)
        np.testing.assert_allclose(np.asarray(res.alphas[t]),
                                   np.asarray(r.alpha),
                                   rtol=1e-12, atol=1e-14)


def test_inactive_tenant_frozen_neighbors_bitwise():
    """An ``active0``-masked tenant's carry never moves (exact zeros ride
    the masked update), while live tenants still match their singles."""
    X, ys = _problem(jnp.float32)
    plan = SolverPlan(b=B, s=S, impl="pallas_interpret", tiles=(8, 256))
    iters = 6
    idx = sample_blocks(jax.random.PRNGKey(3), D, B, iters)
    active0 = jnp.asarray([True, False, True])
    res = s_step_solve_batched("primal", plan, X, _batch("primal", X, ys),
                               iters, idx=idx, active0=active0)
    # frozen tenant: still the cold-start carry, bit-for-bit
    np.testing.assert_array_equal(np.asarray(res.ws[1]), np.zeros(D))
    np.testing.assert_array_equal(np.asarray(res.alphas[1]), np.zeros(N))
    assert not bool(res.active[1])
    for t in (0, 2):
        r = _single("primal", t, plan, X, ys, iters, idx)
        np.testing.assert_array_equal(np.asarray(res.ws[t]), np.asarray(r.w))
        assert bool(res.active[t])


def test_tol_retirement_freezes_carry():
    """With ``tol`` loose enough that every tenant retires after the FIRST
    outer step, a longer solve returns exactly the one-outer-step iterates:
    retired tenants' remaining updates are masked to no-ops."""
    X, ys = _problem(jnp.float32)
    plan = SolverPlan(b=B, s=S, impl="pallas_interpret", tiles=(8, 256))
    idx = sample_blocks(jax.random.PRNGKey(5), D, B, 9)
    long = s_step_solve_batched("primal", plan, X,
                                _batch("primal", X, ys, tol=10.0), 9, idx=idx)
    short = s_step_solve_batched("primal", plan, X, _batch("primal", X, ys),
                                 S, idx=idx[:S])
    assert not bool(long.active.any())
    np.testing.assert_array_equal(np.asarray(long.ws), np.asarray(short.ws))
    np.testing.assert_array_equal(np.asarray(long.alphas),
                                  np.asarray(short.alphas))


def test_warm_resume_bitwise():
    """carry0/active0 chunked resume == one uninterrupted solve: the serve
    front end's chunking must not perturb iterates."""
    X, ys = _problem(jnp.float32)
    plan = SolverPlan(b=B, s=S, impl="pallas_interpret", tiles=(8, 256))
    iters = 12
    idx = sample_blocks(jax.random.PRNGKey(11), D, B, iters)
    whole = s_step_solve_batched("primal", plan, X, _batch("primal", X, ys),
                                 iters, idx=idx)
    half = s_step_solve_batched("primal", plan, X, _batch("primal", X, ys),
                                6, idx=idx[:6])
    resumed = s_step_solve_batched(
        "primal", plan, X, _batch("primal", X, ys), 6, idx=idx[6:],
        carry0=(half.ws, half.alphas), active0=half.active)
    np.testing.assert_array_equal(np.asarray(resumed.ws),
                                  np.asarray(whole.ws))
    np.testing.assert_array_equal(np.asarray(resumed.alphas),
                                  np.asarray(whole.alphas))


# ---------------------------------------------------------------------------
# Continuous-batching front end
# ---------------------------------------------------------------------------

def test_solver_service_converges_to_exact():
    """Requests stream through slots/chunks/retirement and land on the
    closed-form ridge solution."""
    from repro.serve.solver_service import SolverService, SolverServiceConfig
    X, ys = _problem(jnp.float32)
    svc = SolverService(X, SolverPlan(b=B, s=S, impl="ref"), "primal",
                        SolverServiceConfig(slots=4, min_bucket=2,
                                            chunk_iters=48, max_iters=480))
    rids = [svc.submit(np.asarray(ys[t]), LAMS[t]) for t in range(T)]
    done = svc.serve()
    assert sorted(done) == sorted(rids)
    for t, rid in enumerate(rids):
        ticket = svc.result(rid)
        assert ticket.iters == 480 and not ticket.converged
        w_exact = np.asarray(ridge_exact(X, ys[t], LAMS[t]))
        err = np.linalg.norm(ticket.w - w_exact) / np.linalg.norm(w_exact)
        assert err < 1e-4, (t, err)


def test_solver_service_tol_retirement_oversubscribed():
    """More requests than slots; the dual's residual IS a convergence
    statistic, so per-request tolerances retire tenants early and free
    slots for the queue."""
    from repro.serve.solver_service import SolverService, SolverServiceConfig
    X, _ = _problem(jnp.float32)
    svc = SolverService(X, SolverPlan(b=B, s=S, impl="ref"), "dual",
                        SolverServiceConfig(slots=2, min_bucket=2,
                                            chunk_iters=64, max_iters=1280))
    rids = [svc.submit(
        np.asarray(jax.random.normal(jax.random.PRNGKey(20 + i), (N,),
                                     jnp.float32)),
        0.3 + 0.2 * i, tol=1e-4) for i in range(4)]
    done = svc.serve()
    assert sorted(done) == sorted(rids)
    for rid in rids:
        t = svc.result(rid)
        assert t.converged and t.residual <= 1e-4
    # 4 requests through 2 slots: one compiled shape total
    assert list(svc._solve_cache) == [(2, "dual", ())]

"""Sharded fault/recovery checks run in a subprocess with an 8-device CPU
world (tests/test_faults.py drives this; the main pytest process keeps 1
device).  Each check asserts internally, prints ``<name> OK``, and exits
nonzero on failure.  f64 is enabled process-wide: the elastic-resume
acceptance is a 1e-10 bit-tolerance claim.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.bcd import objective  # noqa: E402
from repro.core.distributed import (ca_bcd_sharded, ca_bdcd_sharded,  # noqa: E402
                                    make_solver_mesh)
from repro.core.engine import (GUARD_MAGNITUDE, GUARD_NONFINITE,  # noqa: E402
                               GUARD_SHARD_LOSS, sample_blocks)
from repro.core.proximal import (ca_proximal_bcd_sharded,  # noqa: E402
                                 elastic_net_objective)
from repro.faults import FaultPlan, solve_supervised  # noqa: E402

D, N, B, S, ITERS = 16, 48, 2, 3, 30
LAM = 1e-2


def _problem(dual=False):
    X = jax.random.normal(jax.random.key(0), (D, N), jnp.float64)
    y = jax.random.normal(jax.random.key(1), (N,), jnp.float64)
    idx = sample_blocks(jax.random.key(2), N if dual else D, B, ITERS)
    return X, y, idx


def _get(tree):
    return {k: np.asarray(jax.device_get(v)).item() for k, v in tree.items()}


def check_fault_matrix_sharded():
    """{nan_packet, bitflip, drop_shard} x {primal, dual, proximal} on the
    8-device mesh: one shard's contribution is corrupted at a chosen outer
    step; the fused health word detects it at exactly that step with the
    right reason bit, all shards branch identically (no divergence / hang),
    and the degraded solve still reaches a converged objective."""
    mesh = make_solver_mesh(8)
    cases = [("nan_packet", 2, GUARD_NONFINITE),
             ("bitflip", 1, GUARD_MAGNITUDE),
             ("drop_shard", 2, GUARD_SHARD_LOSS)]
    solvers = {
        "primal": (ca_bcd_sharded, False, {},
                   lambda X, w, y: objective(X, w, y, LAM)),
        "dual": (ca_bdcd_sharded, True, {},
                 lambda X, w, y: objective(X, w, y, LAM)),
        "proximal": (ca_proximal_bcd_sharded, False, {"lam1": 1e-3},
                     lambda X, w, y: elastic_net_objective(X, w, y, LAM,
                                                           1e-3)),
    }
    for fname, (solve, dual, kw, obj) in solvers.items():
        X, y, idx = _problem(dual)
        wc, _ = solve(mesh, X, y, LAM, B, S, ITERS, None, idx=idx, **kw)
        o_clean = float(obj(X, np.asarray(jax.device_get(wc)), y))
        for kind, step, reason in cases:
            fault = FaultPlan(kind, step=step, shard=5)
            w, _, m = solve(mesh, X, y, LAM, B, S, ITERS, None, idx=idx,
                            guard=True, fault=fault, **kw)
            m = _get(m)
            assert m["guard_trips"] >= 1, (fname, kind, m)
            assert m["guard_first_trip"] == step, (fname, kind, m)
            assert int(m["guard_first_reason"]) & reason, (fname, kind, m)
            # near the clean objective: the fault cost at most the skipped
            # outer step, not a blowup (see test_faults.py on the bound).
            o = float(obj(X, np.asarray(jax.device_get(w)), y))
            assert np.isfinite(o), (fname, kind)
            assert o <= o_clean * 1.25 + 1e-9, (fname, kind, o, o_clean)
        print(f"  {fname}: matrix ok (clean obj {o_clean:.6f})")
    print("fault_matrix_sharded OK")


def check_fault_parity_pipelined():
    """Pipelined x faults: a guard trip under the ring wire degrades
    IDENTICALLY to the psum backend -- same trip step, same reason bits,
    same s=1 degraded tail -- because the fault hooks fire at packet
    CONSUMPTION (after the reduction, whichever wire carried it) and the
    ring sums the presence flags just like the psum does.  nan_packet also
    proves NaN propagates through the chunked ppermute chain."""
    from repro.core.distributed import ca_bcd_pipelined
    mesh = make_solver_mesh(8)
    X, y, idx = _problem()
    cases = [("nan_packet", 2, GUARD_NONFINITE),
             ("drop_shard", 2, GUARD_SHARD_LOSS)]
    for kind, step, reason in cases:
        fault = FaultPlan(kind, step=step, shard=5)
        w_r, _, m_r = ca_bcd_pipelined(mesh, X, y, LAM, B, S, ITERS, None,
                                       idx=idx, guard=True, fault=fault)
        w_p, _, m_p = ca_bcd_sharded(mesh, X, y, LAM, B, S, ITERS, None,
                                     idx=idx, guard=True, fault=fault)
        m_r, m_p = _get(m_r), _get(m_p)
        assert m_r["guard_first_trip"] == step, (kind, m_r)
        assert int(m_r["guard_first_reason"]) & reason, (kind, m_r)
        # verdict-for-verdict identical degradation vs the psum backend
        for k in ("guard_trips", "guard_first_trip", "guard_first_reason"):
            assert m_r[k] == m_p[k], (kind, k, m_r, m_p)
        # ...and the degraded iterates agree to the wire-order tolerance
        # (ring chain vs psum tree: ~1e-12 relative in f64, not bit-for-bit)
        np.testing.assert_allclose(np.asarray(jax.device_get(w_r)),
                                   np.asarray(jax.device_get(w_p)),
                                   rtol=1e-12, atol=1e-14)
        o = float(objective(X, np.asarray(jax.device_get(w_r)), y, LAM))
        assert np.isfinite(o), kind
        print(f"  {kind}: trip@{m_r['guard_first_trip']} "
              f"reason={int(m_r['guard_first_reason'])} parity ok")
    print("fault_parity_pipelined OK")


def check_supervised_resume_sharded():
    """THE acceptance case: device loss at outer step 2 kills the 8-device
    solve; the supervisor restores the newest CRC-valid snapshot, re-plans a
    4-device mesh, re-pads the operands, and finishes -- matching the
    uninterrupted 8-device solve's objective (and iterate) to 1e-10 in f64,
    on both even and ragged ``iters % s != 0`` schedules, on ref and
    pallas_interpret backends."""
    import tempfile
    X, y, _ = _problem()
    for impl in ("ref", "pallas_interpret"):
        for iters in (30, 29):                     # 30 % 3 == 0, 29 % 3 == 2
            idx = sample_blocks(jax.random.key(2), D, B, iters)
            with tempfile.TemporaryDirectory() as td:
                fault = FaultPlan("device_loss", step=2, survivors=4)
                res = solve_supervised(
                    "primal", "sharded", X, y, LAM, B, S, iters, None,
                    idx=idx, ckpt_dir=td, fault=fault, impl=impl)
            assert res.metrics["restarts"] == 1, res.metrics
            assert res.metrics["final_n_shards"] == 4, res.metrics
            assert res.metrics["resumed_from_iter"] > 0, res.metrics
            wu, _ = ca_bcd_sharded(make_solver_mesh(8), X, y, LAM, B, S,
                                   iters, None, idx=idx, impl=impl)
            w_res = np.asarray(jax.device_get(res.w))
            w_un = np.asarray(jax.device_get(wu))
            drift = float(np.max(np.abs(w_res - w_un)))
            o_res = float(objective(X, w_res, y, LAM))
            o_un = float(objective(X, w_un, y, LAM))
            assert drift < 1e-10, (impl, iters, drift)
            assert abs(o_res - o_un) < 1e-10, (impl, iters, o_res, o_un)
            print(f"  impl={impl} iters={iters}: drift={drift:.2e}")
    print("supervised_resume_sharded OK")


def check_supervised_resume_local():
    """Local-backend supervised resume at f64: restart from snapshot matches
    the uninterrupted solve to 1e-10 on even and ragged schedules."""
    import tempfile

    from repro.core.bcd import ca_bcd
    X, y, _ = _problem()
    for iters in (30, 29):
        idx = sample_blocks(jax.random.key(2), D, B, iters)
        with tempfile.TemporaryDirectory() as td:
            fault = FaultPlan("device_loss", step=4)
            res = solve_supervised("primal", "local", X, y, LAM, B, S, iters,
                                   None, idx=idx, ckpt_dir=td, fault=fault)
        assert res.metrics["restarts"] == 1, res.metrics
        clean = ca_bcd(X, y, LAM, B, S, iters, None, idx=idx)
        drift = float(np.max(np.abs(np.asarray(res.w) - np.asarray(clean.w))))
        assert drift < 1e-10, (iters, drift)
        print(f"  iters={iters}: drift={drift:.2e}")
    print("supervised_resume_local OK")


CHECKS = {f.__name__.replace("check_", ""): f for f in
          (check_fault_matrix_sharded, check_fault_parity_pipelined,
           check_supervised_resume_sharded, check_supervised_resume_local)}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()

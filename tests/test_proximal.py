"""Registry-level tests for the proximal (elastic-net) formulation -- the
first formulation added *through* the engine's registry (PR 4 tentpole).

Covers the acceptance criteria:
  * ``lam1=0`` reproduces the ridge (``bcd``) iterates bit-for-bit through
    ``get_solver`` (the prox sweep lowers to the ridge sweep, statically);
  * s=1 matches a hand-rolled classical proximal reference;
  * s>1 matches the classical schedule, ragged ``iters % s != 0`` included,
    on both ``ref`` and ``pallas_interpret``;
  * the soft-threshold produces EXACT zeros and the elastic-net metrics;
  * the prox-aware sweep equals the ridge sweep at tau=0.
(The sharded path's equivalence + 1-all-reduce-per-outer-iteration claim is
asserted in tests/dist_checks.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (block_forward_substitution,
                        block_forward_substitution_prox, get_solver,
                        overlap_matrix, proximal_bcd, proximal_bcd_reference,
                        sample_blocks, soft_threshold, s_step_solve,
                        SolverPlan)
from repro.core.proximal import ProximalElasticNet
from repro.data import SyntheticSpec, make_regression

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=40, n=120, cond=1e4))
    return X, y


def _lam1_for(X, y, frac=0.1):
    # relative to the lasso critical value max|X y| / n, below which the
    # solution is not identically zero
    return frac * float(jnp.max(jnp.abs(X @ y)) / X.shape[1])


# --------------------------------------------------------------------------
# lam1 = 0 IS ridge, bit-for-bit, through the registry
# --------------------------------------------------------------------------

def test_lam1_zero_is_ridge_bit_for_bit(problem):
    X, y = problem
    idx = sample_blocks(jax.random.key(1), X.shape[0], 4, 20)
    prox = get_solver("proximal", "local")
    ridge = get_solver("primal", "local")
    for s in (1, 3):
        r_p = prox(X, y, LAM, 4, s, 20, None, idx=idx, lam1=0.0)
        r_r = ridge(X, y, LAM, 4, s, 20, None, idx=idx)
        assert np.array_equal(np.asarray(r_p.w), np.asarray(r_r.w))
        assert np.array_equal(np.asarray(r_p.alpha), np.asarray(r_r.alpha))


# --------------------------------------------------------------------------
# s=1 == the hand-rolled classical proximal reference
# --------------------------------------------------------------------------

def test_engine_s1_is_classical_proximal(problem):
    X, y = problem
    lam1 = _lam1_for(X, y)
    idx = sample_blocks(jax.random.key(2), X.shape[0], 4, 25)
    res = proximal_bcd(X, y, LAM, 4, 25, None, lam1=lam1, idx=idx)
    w_ref, al_ref = proximal_bcd_reference(X, y, LAM, lam1, 4, 25, idx)
    np.testing.assert_allclose(res.w, w_ref, rtol=0, atol=1e-12)
    np.testing.assert_allclose(res.alpha, al_ref, rtol=0, atol=1e-12)


def test_s_step_solve_accepts_formulation_name(problem):
    """The registry string route: s_step_solve('proximal', ...) resolves the
    default (lam1=0) instance, and an instance carries its own lam1."""
    X, y = problem
    idx = sample_blocks(jax.random.key(3), X.shape[0], 4, 10)
    r_str = s_step_solve("proximal", SolverPlan(b=4, s=2), X, y, LAM, 10,
                         None, idx=idx)
    r_ridge = s_step_solve("primal", SolverPlan(b=4, s=2), X, y, LAM, 10,
                           None, idx=idx)
    assert np.array_equal(np.asarray(r_str.w), np.asarray(r_ridge.w))
    lam1 = _lam1_for(X, y)
    r_inst = s_step_solve(ProximalElasticNet(lam1=lam1), SolverPlan(b=4, s=2),
                          X, y, LAM, 10, None, idx=idx)
    assert not np.array_equal(np.asarray(r_inst.w), np.asarray(r_ridge.w))


# --------------------------------------------------------------------------
# CA identity with the nonsmooth term: s>1 (ragged included) == classical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("iters,s", [(20, 4), (10, 4), (7, 3), (3, 8)])
def test_ca_proximal_matches_classical(problem, iters, s):
    X, y = problem
    lam1 = _lam1_for(X, y)
    idx = sample_blocks(jax.random.key(4), X.shape[0], 4, iters)
    solve = get_solver("proximal", "local")
    r_cl = solve(X, y, LAM, 4, 1, iters, None, idx=idx, lam1=lam1)
    r_ca = solve(X, y, LAM, 4, s, iters, None, idx=idx, lam1=lam1)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.alpha, r_cl.alpha, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.history["objective"],
                               r_cl.history["objective"], rtol=1e-9, atol=0)
    w_ref, _ = proximal_bcd_reference(X, y, LAM, lam1, 4, iters, idx)
    np.testing.assert_allclose(r_ca.w, w_ref, rtol=1e-11, atol=1e-13)


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_registry_impl_equivalence(problem, impl):
    """ref-vs-pallas_interpret equivalence through the registry with the
    threshold active (ragged s so the tail also runs the kernel backend)."""
    X, y = problem
    lam1 = _lam1_for(X, y)
    idx = sample_blocks(jax.random.key(5), X.shape[0], 4, 10)
    solve = get_solver("proximal", "local")
    r = solve(X, y, LAM, 4, 4, 10, None, idx=idx, lam1=lam1, impl=impl)
    r_ref = solve(X, y, LAM, 4, 4, 10, None, idx=idx, lam1=lam1, impl="ref")
    np.testing.assert_allclose(r.w, r_ref.w, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r.alpha, r_ref.alpha, rtol=0, atol=1e-10)


# --------------------------------------------------------------------------
# Sparsity + metrics
# --------------------------------------------------------------------------

def test_soft_threshold_sparsifies(problem):
    X, y = problem
    lam1 = _lam1_for(X, y, frac=0.3)
    res = proximal_bcd(X, y, LAM, 4, 300, jax.random.key(6), lam1=lam1)
    w = np.asarray(res.w)
    assert np.sum(w != 0) < X.shape[0]      # exact zeros, not small values
    assert int(res.history["nnz"][-1]) == np.sum(w != 0)
    assert res.history["objective"].shape == (300,)
    assert float(res.history["objective"][-1]) < float(
        res.history["objective"][0])


def test_metrics_and_warm_start(problem):
    X, y = problem
    lam1 = _lam1_for(X, y)
    idx = sample_blocks(jax.random.key(7), X.shape[0], 4, 20)
    full = proximal_bcd(X, y, LAM, 4, 20, None, lam1=lam1, idx=idx,
                        w_ref=jnp.ones((X.shape[0],), X.dtype))
    assert full.history["sol_err"].shape == (20,)
    half = proximal_bcd(X, y, LAM, 4, 10, None, lam1=lam1, idx=idx[:10])
    rest = proximal_bcd(X, y, LAM, 4, 10, None, lam1=lam1, idx=idx[10:],
                        w0=half.w)
    np.testing.assert_allclose(rest.w, full.w, rtol=1e-11, atol=1e-13)


# --------------------------------------------------------------------------
# The prox sweep itself
# --------------------------------------------------------------------------

def test_prox_sweep_tau_zero_is_ridge_sweep():
    s, b = 3, 4
    sb = s * b
    k1, k2, k3 = jax.random.split(jax.random.key(8), 3)
    M = jax.random.normal(k1, (sb, sb), jnp.float64)
    A = M @ M.T + sb * jnp.eye(sb, dtype=jnp.float64)
    base = jax.random.normal(k2, (sb,), jnp.float64)
    w0 = jax.random.normal(k3, (sb,), jnp.float64)
    flat = jnp.arange(sb, dtype=jnp.int32)      # distinct: overlap = I
    x_ridge = block_forward_substitution(A, base, s, b)
    x_prox = block_forward_substitution_prox(
        A, base, s, b, w0=w0, tau=jnp.zeros((sb,), jnp.float64),
        overlap=overlap_matrix(flat).astype(A.dtype))
    np.testing.assert_allclose(x_prox, x_ridge, rtol=1e-12, atol=1e-14)


def test_negative_lam1_fails_fast(problem):
    X, y = problem
    with pytest.raises(ValueError, match="lam1"):
        ProximalElasticNet(lam1=-0.1)
    with pytest.raises(ValueError, match="lam1"):
        proximal_bcd(X, y, LAM, 4, 4, None, lam1=-1e-3)


def test_soft_threshold_operator():
    u = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    out = np.asarray(soft_threshold(u, 1.0))
    np.testing.assert_allclose(out, [-1.0, 0.0, 0.0, 0.0, 1.0])
    # S(u, 0) == u bit-for-bit (the lam1=0 identity the engine relies on)
    v = jnp.asarray([-1.75, 3.0, 0.0, 1e-300])
    assert np.array_equal(np.asarray(soft_threshold(v, 0.0)), np.asarray(v))


def test_duplicate_indices_across_blocks(problem):
    """A coordinate re-drawn in a later inner block must see its updated
    value (the overlap recurrence); forced duplicates across blocks."""
    X, y = problem
    lam1 = _lam1_for(X, y)
    idx = jnp.asarray([[0, 1, 2, 3], [2, 3, 4, 5], [0, 5, 6, 7]],
                      jnp.int32)
    solve = get_solver("proximal", "local")
    r_cl = solve(X, y, LAM, 4, 1, 3, None, idx=idx, lam1=lam1)
    r_ca = solve(X, y, LAM, 4, 3, 3, None, idx=idx, lam1=lam1)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    w_ref, _ = proximal_bcd_reference(X, y, LAM, lam1, 4, 3, idx)
    np.testing.assert_allclose(r_ca.w, w_ref, rtol=1e-11, atol=1e-13)

"""Contract-engine checks run in a subprocess with an 8-device CPU world
(tests/test_analysis.py drives this; the main pytest process keeps 1 device).

The mutation checks are the engine's proof of teeth: each registers a
deliberately-broken formulation in the REAL registry and asserts the sweep
fails on it with a message naming the offending op -- a second psum riding
the update (the extra-collective mutation), the PR-2..4 pre-transpose dual
(the operand-layout mutation), and an oversized tuning-table entry (the
VMEM mutation).  Each check asserts internally and exits nonzero on failure.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

import dataclasses  # noqa: E402


def _register_sharded(form):
    """Register ``form`` (instance with a fresh .name) + a sharded solver
    entry with the standard signature, mirroring distributed.py's wrappers."""
    from repro.core.engine import (SolverPlan, register_formulation,
                                   register_solver, s_step_solve_sharded)

    def sharded(mesh, X, y, lam, b, s, iters, key, *, axis="shards",
                fuse_packet=True, idx=None, unroll=1, impl=None, tiles=None,
                guard=False, fault=None, x0=None, step0=0):
        plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                          fuse_packet=fuse_packet, unroll=unroll,
                          guard=guard, fault=fault)
        return s_step_solve_sharded(form, plan, mesh, X, y, lam, iters, key,
                                    axis=axis, idx=idx, x0=x0, step0=step0)

    register_formulation(form)
    register_solver(form.name, "sharded", sharded)


def check_sweep_pass():
    """The full sweep passes on every registered solver lowering, and the
    report carries the expected case matrix."""
    from repro.analysis import run_sweep

    report = run_sweep()
    assert report.ok, "\n" + report.summary()
    hlo = next(p for p in report.passes if p.name == "hlo")
    # 3 ridge-family formulations x (4 local + 8 sharded + 1 x64 + 6 guard
    # + 4 batched + 6 pipelined + 2 pipelined-batched) = 93, plus the
    # accelerated formulation (not tenant-batched) at 25.
    assert len(hlo.cases) == 118, hlo.cases
    assert not hlo.skipped, hlo.skipped
    plan = next(p for p in report.passes if p.name == "plan")
    assert len(plan.cases) >= 11, plan.cases
    print("sweep_pass OK")


def check_mutation_second_psum():
    """A formulation whose update sneaks a SECOND psum onto the wire must
    fail the collective-count contract, naming the extra op."""
    from repro.core.engine import PrimalRidge, _BoundPrimal

    @dataclasses.dataclass(frozen=True)
    class _SecondPsumBound(_BoundPrimal):
        def update(self, carry, idx, dx, pp):
            # The mutation: a per-update reduction (results used, so XLA
            # cannot dead-code it away; /8 keeps the math ~fixed-point).
            dx = jax.lax.psum(dx, "shards") / 8.0
            return super().update(carry, idx, dx, pp)

    class SecondPsumPrimal(PrimalRidge):
        name = "evil-second-psum"

        def bind_shard(self, Xl, yl, lam, *, d, n):
            bound = super().bind_shard(Xl, yl, lam, d=d, n=n)
            return _SecondPsumBound(**{f.name: getattr(bound, f.name)
                                       for f in dataclasses.fields(bound)})

    _register_sharded(SecondPsumPrimal())

    from repro.analysis import run_hlo_pass
    rep = run_hlo_pass(formulations=["evil-second-psum"])
    assert not rep.ok, "sweep failed to catch the second psum"
    counts = [v for v in rep.violations if v.check == "collective-count"]
    assert counts, rep.violations
    v = counts[0]
    assert "evil-second-psum/sharded" in v.subject, v
    assert "all-reduce" in v.message, v  # names the offending ops
    print("found:", v)
    print("mutation_second_psum OK")


def check_mutation_health_guard():
    """A formulation claiming ``health_in_packet`` whose update adds a
    second psum must fail the GUARD-armed collective-count sweep -- the
    zero-extra-collectives guarantee has teeth, not just the base budget."""
    from repro.core.engine import PrimalRidge, SolverContracts, _BoundPrimal

    @dataclasses.dataclass(frozen=True)
    class _GuardPsumBound(_BoundPrimal):
        def update(self, carry, idx, dx, pp):
            dx = jax.lax.psum(dx, "shards") / 8.0
            return super().update(carry, idx, dx, pp)

    class GuardPsumPrimal(PrimalRidge):
        name = "evil-guard-psum"

        def contracts(self):
            return SolverContracts(health_in_packet=True)

        def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
            bound = super().bind_shard(Xl, yl, lam, d=d, n=n, x0=x0)
            return _GuardPsumBound(**{f.name: getattr(bound, f.name)
                                      for f in dataclasses.fields(bound)})

    _register_sharded(GuardPsumPrimal())

    from repro.analysis import run_hlo_pass
    rep = run_hlo_pass(formulations=["evil-guard-psum"])
    assert not rep.ok, "sweep failed to catch the guarded second psum"
    counts = [v for v in rep.violations if v.check == "collective-count"]
    assert counts, rep.violations
    guarded = [v for v in counts if ",guard]" in v.subject]
    assert guarded, counts   # specifically the guard-armed lowerings fail
    v = guarded[0]
    assert "evil-guard-psum/sharded" in v.subject, v
    assert "all-reduce" in v.message, v
    print("found:", v)
    print("mutation_health_guard OK")


def _register_pipelined(form):
    """Register ``form`` + a PIPELINED solver entry (ring wire), mirroring
    distributed.py's ca_*_pipelined wrappers."""
    from repro.core.engine import (SolverPlan, register_formulation,
                                   register_solver, s_step_solve_sharded)

    def pipelined(mesh, X, y, lam, b, s, iters, key, *, axis="shards",
                  fuse_packet=True, idx=None, unroll=1, impl=None, tiles=None,
                  guard=False, fault=None, x0=None, step0=0):
        plan = SolverPlan(b=b, s=s, impl=impl, tiles=tiles,
                          fuse_packet=fuse_packet, unroll=unroll,
                          guard=guard, fault=fault, wire="ring")
        return s_step_solve_sharded(form, plan, mesh, X, y, lam, iters, key,
                                    axis=axis, idx=idx, x0=x0, step0=step0)

    register_formulation(form)
    register_solver(form.name, "pipelined", pipelined)


def check_mutation_extra_hop():
    """A pipelined lowering that sneaks a second reduction -- an UN-DECLARED
    psum riding the update next to the declared collective-permute ring --
    must fail the sweep with a message naming the op.  This is the teeth of
    the wire-schedule declaration: the ring contract pins the KIND, so any
    all-reduce in a pipelined lowering is flagged even though the same op is
    legal (and counted) under the psum backend."""
    from repro.core.engine import PrimalRidge, SolverContracts, _BoundPrimal

    @dataclasses.dataclass(frozen=True)
    class _ExtraHopBound(_BoundPrimal):
        def update(self, carry, idx, dx, pp):
            # The mutation: a monolithic psum next to the declared ring.
            dx = jax.lax.psum(dx, "shards") / 8.0
            return super().update(carry, idx, dx, pp)

    class ExtraHopPrimal(PrimalRidge):
        name = "evil-extra-hop"

        def contracts(self):
            # Plain contract: no guard/batched cases; the pipelined branch
            # still runs because the backend entry below is registered.
            return SolverContracts()

        def bind_shard(self, Xl, yl, lam, *, d, n, x0=None):
            bound = super().bind_shard(Xl, yl, lam, d=d, n=n, x0=x0)
            return _ExtraHopBound(**{f.name: getattr(bound, f.name)
                                     for f in dataclasses.fields(bound)})

    _register_pipelined(ExtraHopPrimal())

    from repro.analysis import run_hlo_pass
    rep = run_hlo_pass(formulations=["evil-extra-hop"])
    assert not rep.ok, "sweep failed to catch the extra reduction"
    kinds = [v for v in rep.violations if v.check == "collective-kind"]
    assert kinds, rep.violations
    v = kinds[0]
    assert "evil-extra-hop/pipelined" in v.subject, v
    assert "all-reduce" in v.message, v  # names the offending op
    print("found:", v)
    print("mutation_extra_hop OK")


def check_mutation_pretranspose():
    """The PR-2..4 pre-transpose dual registered as a formulation must fail
    the operand-transpose contract, naming the transpose op."""
    from _legacy_dual import LegacyPreTransposeDual

    class MutantDual(LegacyPreTransposeDual):
        name = "evil-pretranspose"

    _register_sharded(MutantDual())

    from repro.analysis import run_hlo_pass
    rep = run_hlo_pass(formulations=["evil-pretranspose"])
    assert not rep.ok, "sweep failed to catch the pre-transpose"
    trs = [v for v in rep.violations if v.check == "operand-transpose"]
    assert trs, rep.violations
    v = trs[0]
    assert "evil-pretranspose/sharded" in v.subject, v
    assert "transpose" in v.message, v
    print("found:", v)
    print("mutation_pretranspose OK")


def check_mutation_oversized_tile():
    """An autotune-table entry whose tiles blow the VMEM budget must fail
    the plan pass, naming the entry.  Runs in this throwaway process because
    register_table mutates the live table."""
    from repro.analysis import run_plan_pass
    from repro.kernels.gram.tuning import register_table

    assert run_plan_pass().ok  # clean before the mutation
    # 2 panels + 2 lane slabs at (32, 4096, f32, cols) ~= 128 MiB >> 16 MiB
    register_table({"4096,8192,float32,cols": (32, 4096)})
    rep = run_plan_pass()
    assert not rep.ok, "plan pass failed to catch the oversized tile"
    vmem = [v for v in rep.violations if v.check == "vmem-budget"]
    assert vmem, rep.violations
    v = vmem[0]
    assert "bm=32" in v.message and "bk=4096" in v.message, v
    assert "4096,8192,float32,cols" in v.subject, v
    print("found:", v)
    print("mutation_oversized_tile OK")


CHECKS = {f.__name__.replace("check_", ""): f for f in
          (check_sweep_pass, check_mutation_second_psum,
           check_mutation_health_guard, check_mutation_extra_hop,
           check_mutation_pretranspose, check_mutation_oversized_tile)}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()

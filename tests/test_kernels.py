"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle,
swept over shapes (tile multiples and ragged) and dtypes, plus hypothesis
(skipped with a reason when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st

from repro.kernels.gram import (ColMajorOperand, gram, gram_packet,
                                gram_packet_ref, gram_packet_sampled,
                                gram_packet_sampled_cols_ref,
                                gram_packet_sampled_ref, panel_apply,
                                panel_apply_cols_ref, tuning)

SHAPES = [(128, 512), (64, 300), (96, 1024), (8, 128), (130, 700), (256, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gram_packet_matches_ref(shape, dtype):
    m, n = shape
    A = jax.random.normal(jax.random.key(0), (m, n), dtype)
    u = jax.random.normal(jax.random.key(1), (n,), dtype)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.01,
                         impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.01)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(G1, G0, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r0, rtol=tol, atol=tol)


def test_gram_symmetric_skip_equals_full():
    A = jax.random.normal(jax.random.key(2), (128, 512), jnp.float32)
    u = jnp.zeros((512,), jnp.float32)
    G_skip, _ = gram_packet(A, u, impl="pallas_interpret", symmetric_skip=True)
    G_full, _ = gram_packet(A, u, impl="pallas_interpret", symmetric_skip=False)
    np.testing.assert_allclose(G_skip, G_full, rtol=1e-6, atol=1e-6)


def test_gram_output_symmetric():
    A = jax.random.normal(jax.random.key(3), (192, 384), jnp.float32)
    G = gram(A, scale=0.5, reg=1.0, impl="pallas_interpret")
    np.testing.assert_allclose(G, G.T, rtol=0, atol=0)  # exact by construction


def test_reg_on_diagonal_only():
    A = jnp.zeros((64, 128), jnp.float32)
    G = gram(A, reg=2.5, impl="pallas_interpret")
    np.testing.assert_allclose(G, 2.5 * jnp.eye(64), atol=0)


@given(m=st.integers(4, 80), n=st.integers(16, 400), seed=st.integers(0, 999))
def test_gram_property_ragged_shapes(m, n, seed):
    A = jax.random.normal(jax.random.key(seed), (m, n), jnp.float32)
    u = jax.random.normal(jax.random.key(seed + 1), (n,), jnp.float32)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.1, impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.1)
    np.testing.assert_allclose(G1, G0, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(r1, r0, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(96, 512), (40, 300), (13, 128)])
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gram_packet_sampled_matches_ref(shape, dtype):
    """The index-prefetched gather kernel vs the jnp oracle, including
    out-of-order and duplicate indices and ragged (m, n)."""
    m, n = shape
    d = 2 * max(m, 16)
    X = jax.random.normal(jax.random.key(10), (d, n), dtype)
    u = jax.random.normal(jax.random.key(11), (n,), dtype)
    flat = jax.random.randint(jax.random.key(12), (m,), 0, d, jnp.int32)
    G1, r1 = gram_packet_sampled(X, flat, u, scale=1.0 / n, reg=0.01,
                                 impl="pallas_interpret")
    G0, r0 = gram_packet_sampled_ref(X, flat, u, 1.0 / n, 0.01)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(G1, G0, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r0, rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(96, 512), (40, 300), (13, 128)])
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gram_packet_sampled_cols_matches_ref(shape, dtype):
    """The lane-slab column-gather kernel vs the jnp oracle: m sampled
    columns of a (d, n) operand in its ORIGINAL layout, including
    out-of-order and duplicate indices and ragged (m, d, n)."""
    m, d = shape
    pool = 2 * max(m, 16) + 5           # ragged column count (n % 128 != 0)
    X = jax.random.normal(jax.random.key(20), (d, pool), dtype)
    u = jax.random.normal(jax.random.key(21), (d,), dtype)
    flat = jax.random.randint(jax.random.key(22), (m,), 0, pool, jnp.int32)
    G1, r1 = gram_packet_sampled(ColMajorOperand(X), flat, u, scale=1.0 / d,
                                 reg=0.01, impl="pallas_interpret")
    G0, r0 = gram_packet_sampled_cols_ref(X, flat, u, 1.0 / d, 0.01)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(G1, G0, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r0, rtol=tol, atol=tol)


def test_panel_apply_cols_matches_ref():
    # f32 (this module runs without the x64 fixture): f32-level tolerances.
    d, pool = 31, 200
    X = jax.random.normal(jax.random.key(23), (d, pool), jnp.float32)
    flat = jnp.asarray([3, 3, 0, 199, 8], jnp.int32)
    v = jax.random.normal(jax.random.key(24), (5,), jnp.float32)
    a0 = 0.7 * X[:, flat] @ v
    np.testing.assert_allclose(panel_apply_cols_ref(X, flat, v, 0.7), a0,
                               rtol=1e-5, atol=1e-5)
    for impl in ("ref", "pallas_interpret"):
        a1 = panel_apply(ColMajorOperand(X), flat, v, scale=0.7, impl=impl)
        np.testing.assert_allclose(a1, a0, rtol=1e-5, atol=1e-5)


def test_gram_only_kernel_skips_residual():
    """ops.gram dispatches to the residual-free kernel and still matches the
    packet's G (satellite: no zeros-u wasted work)."""
    A = jax.random.normal(jax.random.key(13), (96, 384), jnp.float32)
    G = gram(A, scale=0.5, reg=1.0, impl="pallas_interpret")
    Gp, _ = gram_packet(A, jnp.zeros((384,), jnp.float32), scale=0.5, reg=1.0,
                        impl="pallas_interpret")
    np.testing.assert_allclose(G, Gp, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(G, G.T, rtol=0, atol=0)


def test_tuning_table_pick_and_override():
    """pick_tiles: table hits and the clamped heuristic fallback; explicit
    (bm, bk) still wins through the dispatch layer."""
    bm, bk = tuning.pick_tiles(13, 70, jnp.float32)
    assert 16 % bm == 0 or bm <= 16   # never exceeds the padded operand
    assert bk <= 128
    assert tuning.pick_tiles(128, 32768, jnp.float32) == (128, 1024)  # table
    A = jax.random.normal(jax.random.key(14), (24, 200), jnp.float32)
    u = jax.random.normal(jax.random.key(15), (200,), jnp.float32)
    G0, r0 = gram_packet(A, u, impl="pallas_interpret")           # autotuned
    G1, r1 = gram_packet(A, u, impl="pallas_interpret", bm=8, bk=128)
    # different tiles reorder the f32 accumulation; values agree to f32 level
    np.testing.assert_allclose(G1, G0, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(r1, r0, rtol=2e-5, atol=2e-5)


def test_tuning_register_and_snapshot():
    snap = tuning.table_snapshot()
    try:
        tuning.register_table({"8,256,float32": (8, 256)})
        assert tuning.pick_tiles(8, 256, jnp.float32) == (8, 256)
    finally:
        tuning._TABLE.clear()
        tuning.register_table(snap)


def test_tuning_layout_dimension():
    """PR-5 satellite: table keys carry the operand layout.  Legacy
    three-field keys load unchanged and mean row-major; a cols entry only
    answers cols lookups; unknown layouts fail fast."""
    snap = tuning.table_snapshot()
    try:
        tuning.register_table({"8,512,float32": (8, 512)})      # legacy key
        assert tuning.pick_tiles(8, 512, jnp.float32) == (8, 512)
        tuning.register_table({"8,512,float32,cols": (8, 64)})
        assert tuning.pick_tiles(8, 512, jnp.float32, layout="cols") == (8, 64)
        # the rows entry is untouched by the cols registration
        assert tuning.pick_tiles(8, 512, jnp.float32) == (8, 512)
        # cols heuristic fallback clamps to the padded operand
        bm, bk = tuning.pick_tiles(5, 24, jnp.float32, layout="cols")
        assert bm <= 8 and bk <= 24
    finally:
        tuning._TABLE.clear()
        tuning.register_table(snap)
    with pytest.raises(ValueError, match="unknown operand layout"):
        tuning.pick_tiles(8, 512, jnp.float32, layout="diagonal")
    with pytest.raises(ValueError, match="unknown operand layout"):
        tuning.register_table({"8,512,float32,diagonal": (8, 64)})


def test_solver_uses_kernel_consistently():
    """ops.gram_packet (ref path) equals the inline Gram the solvers build."""
    A = jax.random.normal(jax.random.key(4), (40, 200), jnp.float32)
    u = jax.random.normal(jax.random.key(5), (200,), jnp.float32)
    n = A.shape[1]
    G, r = gram_packet(A, u, scale=1.0 / n, impl="ref")
    np.testing.assert_allclose(G, A @ A.T / n, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r, A @ u / n, rtol=1e-5, atol=1e-5)

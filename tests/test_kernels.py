"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle,
swept over shapes (tile multiples and ragged) and dtypes, plus hypothesis
(skipped with a reason when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st

from repro.kernels.gram import gram, gram_packet, gram_packet_ref, gram_ref

SHAPES = [(128, 512), (64, 300), (96, 1024), (8, 128), (130, 700), (256, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_gram_packet_matches_ref(shape, dtype):
    m, n = shape
    A = jax.random.normal(jax.random.key(0), (m, n), dtype)
    u = jax.random.normal(jax.random.key(1), (n,), dtype)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.01,
                         impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.01)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(G1, G0, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r0, rtol=tol, atol=tol)


def test_gram_symmetric_skip_equals_full():
    A = jax.random.normal(jax.random.key(2), (128, 512), jnp.float32)
    u = jnp.zeros((512,), jnp.float32)
    G_skip, _ = gram_packet(A, u, impl="pallas_interpret", symmetric_skip=True)
    G_full, _ = gram_packet(A, u, impl="pallas_interpret", symmetric_skip=False)
    np.testing.assert_allclose(G_skip, G_full, rtol=1e-6, atol=1e-6)


def test_gram_output_symmetric():
    A = jax.random.normal(jax.random.key(3), (192, 384), jnp.float32)
    G = gram(A, scale=0.5, reg=1.0, impl="pallas_interpret")
    np.testing.assert_allclose(G, G.T, rtol=0, atol=0)  # exact by construction


def test_reg_on_diagonal_only():
    A = jnp.zeros((64, 128), jnp.float32)
    G = gram(A, reg=2.5, impl="pallas_interpret")
    np.testing.assert_allclose(G, 2.5 * jnp.eye(64), atol=0)


@given(m=st.integers(4, 80), n=st.integers(16, 400), seed=st.integers(0, 999))
def test_gram_property_ragged_shapes(m, n, seed):
    A = jax.random.normal(jax.random.key(seed), (m, n), jnp.float32)
    u = jax.random.normal(jax.random.key(seed + 1), (n,), jnp.float32)
    G1, r1 = gram_packet(A, u, scale=1.0 / n, reg=0.1, impl="pallas_interpret")
    G0, r0 = gram_packet_ref(A, u, 1.0 / n, 0.1)
    np.testing.assert_allclose(G1, G0, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(r1, r0, rtol=2e-5, atol=2e-5)


def test_solver_uses_kernel_consistently():
    """ops.gram_packet (ref path) equals the inline Gram the solvers build."""
    A = jax.random.normal(jax.random.key(4), (40, 200), jnp.float32)
    u = jax.random.normal(jax.random.key(5), (200,), jnp.float32)
    n = A.shape[1]
    G, r = gram_packet(A, u, scale=1.0 / n, impl="ref")
    np.testing.assert_allclose(G, A @ A.T / n, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r, A @ u / n, rtol=1e-5, atol=1e-5)

"""Paper-claim tests for the core solvers (float64).

The central claim (section 3): CA-BCD / CA-BDCD compute the SAME iterates as
BCD / BDCD in exact arithmetic -- communication is restructured, convergence
is untouched.  We verify to ~1e-12 in f64 over multiple (b, s) settings, plus
convergence to the closed-form ridge solution and the CG/TSQR baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bcd, bdcd, ca_bcd, ca_bdcd, cg_ridge,
                        objective, ridge_exact, sample_blocks, tsqr,
                        tsqr_ridge)
from repro.data import SyntheticSpec, make_regression

from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=60, n=200, cond=1e6))
    return X, y, ridge_exact(X, y, LAM)


def test_cg_matches_direct(problem):
    X, y, w_opt = problem
    w = cg_ridge(X, y, LAM, tol=1e-14, max_iters=500).w
    np.testing.assert_allclose(w, w_opt, rtol=1e-10, atol=1e-12)


def test_tsqr_matches_direct(problem):
    X, y, w_opt = problem
    w = tsqr_ridge(X, y, LAM)
    np.testing.assert_allclose(w, w_opt, rtol=1e-9, atol=1e-11)


def test_tsqr_r_factor(problem):
    X, _, _ = problem
    A = X.T  # 200 x 60 tall
    R = tsqr(A, n_blocks=8)
    np.testing.assert_allclose(R.T @ R, A.T @ A, rtol=1e-10, atol=1e-10)


def test_tsqr_dual_path(problem):
    """d > n branch."""
    X, y, _ = problem
    Xt = X.T  # 200 features x 60 points
    yt = jnp.ones((60,), Xt.dtype)
    w = tsqr_ridge(Xt, yt, LAM)
    np.testing.assert_allclose(w, ridge_exact(Xt, yt, LAM), rtol=1e-9,
                               atol=1e-11)


def test_bcd_converges(problem):
    X, y, w_opt = problem
    res = bcd(X, y, LAM, b=8, iters=600, key=jax.random.key(1), w_ref=w_opt)
    assert float(res.history["sol_err"][-1]) < 1e-8
    # objective decreases overall
    obj = res.history["objective"]
    assert float(obj[-1]) < float(obj[0])


@pytest.mark.parametrize("b,s", [(1, 4), (4, 2), (4, 10), (8, 25)])
def test_ca_bcd_exact_equivalence(problem, b, s):
    """CA-BCD(s) == BCD iterate-for-iterate (same sampled blocks)."""
    X, y, w_opt = problem
    iters = 100
    idx = sample_blocks(jax.random.key(2), X.shape[0], b, iters)
    r_cl = bcd(X, y, LAM, b, iters, None, idx=idx, w_ref=w_opt)
    r_ca = ca_bcd(X, y, LAM, b, s, iters, None, idx=idx, w_ref=w_opt)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.history["objective"],
                               r_cl.history["objective"], rtol=1e-9, atol=0)


@pytest.mark.parametrize("b,s", [(1, 4), (8, 5), (16, 25)])
def test_ca_bdcd_exact_equivalence(problem, b, s):
    X, y, w_opt = problem
    iters = 100
    idx = sample_blocks(jax.random.key(3), X.shape[1], b, iters)
    r_cl = bdcd(X, y, LAM, b, iters, None, idx=idx, w_ref=w_opt)
    r_ca = ca_bdcd(X, y, LAM, b, s, iters, None, idx=idx, w_ref=w_opt)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-11, atol=1e-13)
    np.testing.assert_allclose(r_ca.alpha, r_cl.alpha, rtol=1e-11, atol=1e-13)


def test_dual_reaches_primal_solution(problem):
    """BDCD's primal iterate w converges to the same ridge solution."""
    X, y, w_opt = problem
    res = bdcd(X, y, LAM, b=16, iters=3000, key=jax.random.key(4), w_ref=w_opt)
    assert float(res.history["sol_err"][-1]) < 1e-6


def test_single_pass_ca_bcd(problem):
    """s == H: one communication round total (paper Fig. 4 's=H=100' setting)."""
    X, y, w_opt = problem
    iters = 64
    idx = sample_blocks(jax.random.key(5), X.shape[0], 4, iters)
    r_cl = bcd(X, y, LAM, 4, iters, None, idx=idx)
    r_ca = ca_bcd(X, y, LAM, 4, iters, iters, None, idx=idx, track_cond=True)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-10, atol=1e-12)
    assert np.all(np.isfinite(r_ca.history["gram_cond"]))


def test_gram_cond_grows_with_s(problem):
    """Fig. 4i: the sb x sb Gram condition number grows with s but stays
    moderate (numerical-stability claim)."""
    X, y, _ = problem
    conds = []
    for s in (2, 8, 32):
        r = ca_bcd(X, y, LAM, 4, s, 64, jax.random.key(6), track_cond=True)
        conds.append(float(np.max(r.history["gram_cond"])))
    assert conds[0] <= conds[1] <= conds[2]
    assert conds[-1] < 1e8  # well-conditioned even at large s


def test_objective_definition(problem):
    X, y, _ = problem
    w = jnp.ones((X.shape[0],), X.dtype)
    n = X.shape[1]
    expected = 0.5 / n * float(jnp.sum((X.T @ w - y) ** 2)) \
        + 0.5 * LAM * float(w @ w)
    assert abs(float(objective(X, w, y, LAM)) - expected) < 1e-10


def test_residual_alpha_invariant(problem):
    """alpha == X^T w is maintained by the residual-form recurrences."""
    X, y, _ = problem
    res = bcd(X, y, LAM, b=8, iters=50, key=jax.random.key(7))
    np.testing.assert_allclose(res.alpha, X.T @ res.w, rtol=1e-10, atol=1e-12)
    res = ca_bcd(X, y, LAM, b=8, s=5, iters=50, key=jax.random.key(7))
    np.testing.assert_allclose(res.alpha, X.T @ res.w, rtol=1e-10, atol=1e-12)

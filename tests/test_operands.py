"""The PacketOperand layer (PR 5): layout invariance of the dual engine,
raw-array back-compat, and the MaterializedOperand path.

The tentpole claims pinned here:

* the dual engine's iterates are IDENTICAL between the legacy pre-transposed
  operand (PRs 2-4: ``RowMajorOperand(X.T)``, reconstructed outside the
  engine -- the shipped ``DualRidge`` no longer transposes anything) and the
  column-gather operand over the original (d, n) layout -- s=1, s>1, ragged
  tail, sharded, on ref and pallas_interpret, with duplicate and tail-padded
  column indices;
* a pre-materialized kernel-matrix operand (the kernel-BDCD prerequisite,
  arXiv:2406.18001) registers through the operand layer and drives a full
  engine solve with ZERO engine edits -- the formulation below lives in this
  test file and touches only public hooks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolverPlan, bdcd, make_solver_mesh, s_step_solve,
                        s_step_solve_sharded, sample_blocks)
from repro.core.engine import DualRidge
from repro.core.subproblem import block_forward_substitution
from repro.data import SyntheticSpec, make_regression
from repro.kernels.gram import (ColMajorOperand, MaterializedOperand,
                                PacketOperand, RowMajorOperand, as_operand,
                                gram_packet_sampled, gram_packet_sampled_ref,
                                panel_apply, panel_matvec)

from _legacy_dual import LegacyPreTransposeDual
from _x64 import x64_mode  # noqa: F401  (autouse fixture)

LAM = 1e-3
ITERS = 12
# d is a lane multiple so both layouts pad the contraction identically: with
# pinned equal tiles the kernels then run the same dot_generals in the same
# order and the invariance below is exact, not approximate.
D, N = 128, 96


@pytest.fixture(scope="module")
def problem():
    jax.config.update("jax_enable_x64", True)  # before data gen
    X, y, _ = make_regression(jax.random.key(0),
                              SyntheticSpec("t", d=D, n=N, cond=1e4))
    return X, y


def _dup_idx(key, n_total, b, iters):
    """Index stream whose second inner block repeats the first: every CA
    outer block's flat carries exact duplicate column indices."""
    idx = sample_blocks(key, n_total, b, iters)
    return idx.at[1::2].set(idx[0::2])


def _assert_layout_invariant(impl, a, b):
    """pallas_interpret: BIT-FOR-BIT -- with equal pinned tiles both layouts
    gather value-identical panels (the col kernel's one-hot lane select adds
    only exact +0 terms) and then run the same dot_generals in the same
    order, so every iterate is exactly equal.  ref: exact up to XLA fusion --
    the jnp path is reassociation-unstable by construction (fusing the
    residual matvec with the Gram changes its accumulation order even for
    the SAME expression, measurably: the legacy packet fused differs from
    the legacy packet standalone in the last ulp), so the ref assertion is
    a tight f64 allclose instead."""
    a, b = np.asarray(a), np.asarray(b)
    if impl == "pallas_interpret":
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, rtol=1e-11, atol=1e-13)


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("s", [1, 3, 5], ids=["s1", "s3", "ragged-s5"])
def test_dual_layout_invariance(problem, impl, s):
    """Legacy pre-transposed vs column-gather operand: identical dual
    iterates (bit-for-bit on the kernel path, see _assert_layout_invariant).
    s=3 pads the sb=12 tail of every flat to the bm=8 tile (tail-padded
    column indices); s=5 with iters=12 adds the ragged final outer
    iteration; the index stream carries duplicates throughout."""
    X, y = problem
    idx = _dup_idx(jax.random.key(1), N, 4, ITERS)
    # Equal pinned tiles => identical grids and accumulation order in both
    # layouts (d=128 pads the same under the lane and sublane granules).
    tiles = (8, 128) if impl == "pallas_interpret" else None
    plan = SolverPlan(b=4, s=s, impl=impl, tiles=tiles)
    r_leg = s_step_solve(LegacyPreTransposeDual(), plan, X, y, LAM, ITERS,
                         None, idx=idx)
    r_col = s_step_solve(DualRidge(), plan, X, y, LAM, ITERS, None, idx=idx)
    _assert_layout_invariant(impl, r_col.w, r_leg.w)
    _assert_layout_invariant(impl, r_col.alpha, r_leg.alpha)
    _assert_layout_invariant(impl, r_col.history["objective"],
                             r_leg.history["objective"])


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_dual_layout_invariance_sharded(problem, impl):
    """Same invariance through the shard_map backend (single-device mesh in
    this process; the 8-device world re-checks it in dist_checks)."""
    X, y = problem
    mesh = make_solver_mesh(1)
    idx = _dup_idx(jax.random.key(2), N, 4, ITERS)
    tiles = (8, 128) if impl == "pallas_interpret" else None
    plan = SolverPlan(b=4, s=4, impl=impl, tiles=tiles)
    w_leg, al_leg = s_step_solve_sharded(LegacyPreTransposeDual(), plan, mesh,
                                         X, y, LAM, ITERS, None, idx=idx)
    w_col, al_col = s_step_solve_sharded(DualRidge(), plan, mesh, X, y, LAM,
                                         ITERS, None, idx=idx)
    _assert_layout_invariant(impl, w_col, w_leg)
    _assert_layout_invariant(impl, al_col, al_leg)


def test_dual_solver_binds_original_layout(problem):
    """The shipped dual formulation samples the ORIGINAL (d, n) array: the
    bound operand is column-major and holds X itself, not a transposed or
    otherwise re-materialized copy."""
    X, y = problem
    bound = DualRidge().bind(X, y, LAM)
    assert isinstance(bound.operand, ColMajorOperand)
    assert bound.operand.array is X
    assert bound.operand.samples == N and bound.operand.contraction == D
    bound_sh = DualRidge().bind_shard(X, y, LAM, d=D, n=N)
    assert isinstance(bound_sh.operand, ColMajorOperand)
    assert bound_sh.operand.array is X


# --------------------------------------------------------------------------
# Dispatch-level: raw-array back-compat and the column operand's semantics
# --------------------------------------------------------------------------

def test_as_operand_raw_array_means_rows(problem):
    X, _ = problem
    op = as_operand(X)
    assert isinstance(op, RowMajorOperand) and op.array is X
    assert as_operand(op) is op
    col = ColMajorOperand(X)
    assert as_operand(col) is col
    assert isinstance(col, PacketOperand)          # runtime protocol check


def test_colmajor_matches_transposed_rowmajor(problem):
    """ColMajorOperand(X) == RowMajorOperand(X.T) on every entry point: the
    packet, the deferred apply, and the sample-side matvec."""
    X, _ = problem
    flat = jnp.asarray([5, 5, 0, 90, 7, 7, 7, 1, 0, 19, 3, 2, 11], jnp.int32)
    u = jax.random.normal(jax.random.key(3), (D,), jnp.float64)
    v = jax.random.normal(jax.random.key(4), (13,), jnp.float64)
    for impl in ("ref", "pallas_interpret"):
        G0, r0 = gram_packet_sampled(RowMajorOperand(X.T), flat, u,
                                     scale=1.0 / N, reg=0.5, scale_r=2.0,
                                     impl=impl)
        G1, r1 = gram_packet_sampled(ColMajorOperand(X), flat, u,
                                     scale=1.0 / N, reg=0.5, scale_r=2.0,
                                     impl=impl)
        np.testing.assert_allclose(G1, G0, rtol=0, atol=1e-10)
        np.testing.assert_allclose(r1, r0, rtol=0, atol=1e-10)
        a0 = panel_apply(RowMajorOperand(X.T), flat, v, scale=0.7, impl=impl)
        a1 = panel_apply(ColMajorOperand(X), flat, v, scale=0.7, impl=impl)
        np.testing.assert_allclose(a1, a0, rtol=0, atol=1e-10)
    t = jax.random.normal(jax.random.key(5), (D,), jnp.float64)
    m0 = panel_matvec(RowMajorOperand(X.T), flat, t, scale=1.3, impl="ref")
    m1 = panel_matvec(ColMajorOperand(X), flat, t, scale=1.3, impl="ref")
    np.testing.assert_allclose(m1, m0, rtol=0, atol=1e-10)


def test_colmajor_ragged_nonaligned(problem):
    """Ragged everything: d not a sublane multiple, n not a lane multiple,
    duplicate and repeated-0 indices -- pad/unpad is exact in f64."""
    d, n = 23, 70
    X = jax.random.normal(jax.random.key(6), (d, n), jnp.float64)
    u = jax.random.normal(jax.random.key(7), (d,), jnp.float64)
    flat = jnp.asarray([5, 5, 0, 22, 7, 7, 7, 1, 0, 19, 3, 2, 11], jnp.int32)
    G0, r0 = gram_packet_sampled_ref(X.T, flat, u, 1.0 / n, 0.5, 2.0)
    G1, r1 = gram_packet_sampled(ColMajorOperand(X), flat, u, scale=1.0 / n,
                                 reg=0.5, scale_r=2.0,
                                 impl="pallas_interpret")
    assert G1.shape == (13, 13) and r1.shape == (13,)
    np.testing.assert_allclose(G1, G0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r1, r0, rtol=0, atol=1e-10)


# --------------------------------------------------------------------------
# MaterializedOperand: the kernel-BDCD prerequisite, smoke-level
# --------------------------------------------------------------------------

def test_materialized_operand_dispatch(problem):
    """G is GATHERED (scale * K[flat][:, flat] + reg*I), r/apply/matvec run
    against K's sampled rows -- through the same public entry points."""
    X, _ = problem
    K = X.T @ X
    flat = jnp.asarray([3, 3, 0, 40, 8], jnp.int32)
    u = jax.random.normal(jax.random.key(8), (N,), jnp.float64)
    v = jax.random.normal(jax.random.key(9), (5,), jnp.float64)
    op = MaterializedOperand(K)
    assert op.samples == N and op.contraction == N
    for impl in ("ref", "pallas", "pallas_interpret"):
        G, r = gram_packet_sampled(op, flat, u, scale=2.0, reg=0.25,
                                   impl=impl)
        np.testing.assert_allclose(
            G, 2.0 * K[flat][:, flat] + 0.25 * jnp.eye(5), rtol=0, atol=1e-9)
        np.testing.assert_allclose(r, 2.0 * K[flat, :] @ u, rtol=1e-12,
                                   atol=1e-9)
    np.testing.assert_allclose(panel_apply(op, flat, v, scale=0.5),
                               0.5 * K[flat, :].T @ v, rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(panel_matvec(op, flat, u, scale=0.5),
                               0.5 * K[flat, :] @ u, rtol=1e-12, atol=1e-9)


class KernelDualRidge:
    """Smoke-level kernel BDCD (arXiv:2406.18001): the dual formulation over
    a pre-materialized kernel matrix K = X^T X.  Defined ENTIRELY here --
    public Formulation hooks + MaterializedOperand -- which is the proof
    that the operand layer admits the kernel-matrix operand with zero
    engine.py edits.  The carry is (z, alpha) with z = -K alpha / (lam n)
    (the kernel-space image of X^T w), so for the linear kernel the iterates
    must match ``bdcd`` exactly in exact arithmetic."""
    name = "kernel-dual-smoke"
    operand_layout = "materialized"

    def sample_dim(self, d, n):
        return n

    def bind(self, K, y, lam, *, x0=None, w_ref=None):
        n = K.shape[0]
        op = MaterializedOperand(K)

        @dataclasses.dataclass(frozen=True)
        class _Bound:
            operand: object
            scale = 1.0 / (lam * n * n)
            scale_r = -1.0 / (lam * n)
            reg = 1.0 / n

            def init_carry(self, axes=None):
                z = jnp.zeros((n,), K.dtype)
                return z, jnp.zeros((n,), K.dtype)

            def packet_vector(self, carry):
                return carry[1]                       # alpha: r = -K_f a/(ln)

            def base(self, u, carry, flat):
                z, alpha = carry
                return (u - alpha[flat] - y[flat]) / n

            def inner_sweep(self, A, base, s_k, b, flat, carry, overlap=None):
                return block_forward_substitution(A, base, s_k, b)

            def update(self, carry, idx, dx, pp):
                z, alpha = carry
                alpha = alpha.at[idx].add(dx)
                z = z - panel_apply(self.operand, idx, dx,
                                    plan=pp) / (lam * n)
                return z, alpha

            def metrics(self, carry):
                z, alpha = carry
                r = z - y
                w_sq = -(alpha @ z) / (lam * n)       # ||w||^2 via the kernel
                return {"objective": 0.5 / n * (r @ r) + 0.5 * lam * w_sq}

        return _Bound(operand=op)


@pytest.mark.parametrize("s", [1, 4])
def test_materialized_engine_smoke(problem, s):
    """A full engine solve on the kernel-matrix operand: for the linear
    kernel K = X^T X, kernel BDCD == BDCD (alpha and the dual residual
    z = X^T w), s=1 and s>1, through the unmodified engine."""
    X, y = problem
    K = X.T @ X
    idx = sample_blocks(jax.random.key(10), N, 4, ITERS)
    plan = SolverPlan(b=4, s=s, impl="ref")
    res = s_step_solve(KernelDualRidge(), plan, K, y, LAM, ITERS, None,
                       idx=idx)
    ref = bdcd(X, y, LAM, 4, ITERS, None, idx=idx, impl="ref")
    z, alpha = res.w, res.alpha                      # carry = (z, alpha)
    np.testing.assert_allclose(alpha, ref.alpha, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(z, X.T @ ref.w, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(res.history["objective"],
                               ref.history["objective"], rtol=1e-8, atol=0)

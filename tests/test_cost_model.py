"""Cost-model (Table 1/2, Fig 8/9) verification: the structural claims of the
paper hold in our alpha-beta-gamma implementation."""

from repro.core.cost_model import (CORI_MPI, CORI_SPARK, batched_costs,
                                   batched_solves_per_second, bcd_costs,
                                   bdcd_costs, best_s, cg_costs,
                                   strong_scaling, tenant_bytes_per_iter,
                                   tsqr_costs, weak_scaling)

D, N, P, B, H = 1024, 2 ** 22, 1024, 4, 1000


def test_table1_latency_drops_by_s():
    c1 = bcd_costs(D, N, P, B, H, s=1)
    c8 = bcd_costs(D, N, P, B, H, s=8)
    assert abs(c1.latency / c8.latency - 8) < 1e-9


def test_table1_bandwidth_grows_by_about_s():
    c1 = bcd_costs(D, N, P, B, H, s=1)
    c8 = bcd_costs(D, N, P, B, H, s=8)
    ratio = c8.bandwidth / c1.bandwidth
    assert 4 < ratio < 9  # O(s) growth (paper: exactly s at leading order)


def test_table1_flops_grow_by_about_s():
    c1 = bcd_costs(D, N, P, B, H, s=1)
    c8 = bcd_costs(D, N, P, B, H, s=8)
    assert 4 < c8.flops / c1.flops < 9


def test_table1_memory_grows_s_squared_term():
    c1 = bcd_costs(D, N, P, B, H, s=1)
    c8 = bcd_costs(D, N, P, B, H, s=8)
    assert (c8.memory - c1.memory) > 0.8 * (8 ** 2 - 1) * B * B


def test_bdcd_mirrors_bcd():
    cp = bcd_costs(D, N, P, B, H, s=4)
    cd = bdcd_costs(N, D, P, B, H, s=4)  # transposed problem
    assert abs(cp.flops / cd.flops - 1) < 0.1
    assert cp.latency == cd.latency


def test_best_s_never_worse_than_classical():
    for machine in (CORI_MPI, CORI_SPARK):
        t1 = bcd_costs(D, N, P, B, H, 1).time(machine)
        _, ts = best_s(bcd_costs, machine, D, N, P, B, H)
        assert ts <= t1


def test_fig8_strong_scaling_speedups():
    """Modeled strong-scaling speedup reaches the paper's order of magnitude:
    ~14x (MPI) and >100x (Spark) at large P."""
    Ps = [2 ** k for k in range(2, 29, 2)]
    mpi = strong_scaling(CORI_MPI, d=1024, n=2 ** 35, b=4, H=1000, Ps=Ps)
    spark = strong_scaling(CORI_SPARK, d=1024, n=2 ** 40, b=4, H=1000, Ps=Ps)
    assert mpi["speedup"].max() > 5
    assert spark["speedup"].max() > 100
    # speedup grows as communication starts to dominate
    assert mpi["speedup"][-1] > mpi["speedup"][0]


def test_fig9_weak_scaling_speedups():
    Ps = [2 ** k for k in range(2, 29, 2)]
    mpi = weak_scaling(CORI_MPI, d=1024, n_per_P=2 ** 11, b=4, H=1000, Ps=Ps)
    spark = weak_scaling(CORI_SPARK, d=1024, n_per_P=2 ** 11, b=4, H=1000,
                         Ps=Ps)
    assert mpi["speedup"].max() > 5
    assert spark["speedup"].max() > 100


def test_table2_tsqr_single_reduction():
    assert tsqr_costs(D, N, P).latency < cg_costs(D, N, P, 100).latency


def test_batched_sync_term_independent_of_tenants():
    """DESIGN.md section 8: the latency term is per BATCH -- T tenants, one
    psum -- while bandwidth picks up exactly T*sb extra words per step."""
    c1 = batched_costs(D, N, P, B, H, s=8, tenants=1)
    c64 = batched_costs(D, N, P, B, H, s=8, tenants=64)
    assert c1.latency == c64.latency
    sb = 8 * B
    from repro.core.cost_model import _logp
    assert abs((c64.bandwidth - c1.bandwidth)
               - (H / 8) * 63 * sb * _logp(P)) < 1e-6
    # T=1 reduces to the single-solve Theorem 6 costs
    s1 = bcd_costs(D, N, P, B, H, s=8)
    assert abs(c1.flops / s1.flops - 1) < 1e-9
    assert c1.latency == s1.latency


def test_batched_amortization_curves():
    """Latency-dominated machine: solves/s grows ~linearly with T; wire
    bytes per iteration per tenant fall toward the per-tenant floor."""
    kw = dict(d=D, n=N, P=P, b=B, H=H, s=8)
    r1 = batched_solves_per_second(CORI_SPARK, tenants=1, **kw)
    r64 = batched_solves_per_second(CORI_SPARK, tenants=64, **kw)
    assert r64 / r1 > 10      # the serve-bench acceptance line, modeled
    assert (tenant_bytes_per_iter(D, N, P, B, 8, 64)
            < tenant_bytes_per_iter(D, N, P, B, 8, 1) / 10)


def test_costs_positive():
    for c in (bcd_costs(D, N, P, B, H, 4), bdcd_costs(D, N, P, B, H, 4),
              cg_costs(D, N, P, 50), tsqr_costs(D, N, P)):
        assert min(c.flops, c.latency, c.bandwidth, c.memory) > 0

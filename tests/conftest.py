import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # hypothesis is optional: property tests skip via _hyp
    settings = None

# Keep the device world at 1 (the multi-pod dry-run runs in its own process);
# distributed tests spawn subprocesses with their own XLA_FLAGS.
if settings is not None:
    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")


@pytest.fixture()
def rng_key():
    import jax
    return jax.random.key(0)

"""Serving-path correctness: prefill+decode == full forward (f32), the slot
engine reproduces step-by-step greedy decoding, mamba state continuity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import synthetic_lm_batch
from repro.models import api, init_params
from repro.serve import Engine, ServeConfig

ARCHS = ["llama3_2_3b", "qwen2_0_5b", "mamba2_370m", "jamba_1_5_large_398b",
         "seamless_m4t_large_v2", "phi3_5_moe_42b", "llava_next_34b"]


def _f32(arch):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32,
                               param_dtype=jnp.float32)


# jamba's prefill+decode comparison is the file's slowest case (~17s on CPU);
# the PR gate runs `-m "not slow"`, the full tier-1 suite still covers it.
_PREFILL_ARCHS = [pytest.param(a, marks=pytest.mark.slow)
                  if a == "jamba_1_5_large_398b" else a for a in ARCHS]


@pytest.mark.parametrize("arch", _PREFILL_ARCHS)
def test_prefill_decode_equals_forward(arch):
    cfg = _f32(arch)
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    B, S = 2, 32
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_lm_batch(cfg.vocab, S, B).items()}
    if cfg.family == "audio":
        batch["src_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(1), (B, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["extra_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(1), (B, cfg.frontend_tokens, cfg.d_model),
            jnp.float32)
        # decode path below tests pure-text; vlm covered by prefill only
    logits_full, _ = jax.jit(lambda p, b: api.forward(p, cfg, b))(params, batch)

    if cfg.family == "vlm":
        pytest.skip("vlm decode covered via dense decoder path (same body)")

    pre = dict(batch, tokens=batch["tokens"][:, :S - 1])
    logits_pre, cache = jax.jit(
        lambda p, b: api.prefill(p, cfg, b, max_seq=S))(params, pre)
    np.testing.assert_allclose(logits_pre, logits_full[:, S - 2, :],
                               rtol=1e-3, atol=1e-3)

    tok = batch["tokens"][:, S - 1]
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits_dec, _ = jax.jit(
        lambda p, c, t, q: api.decode_step(p, cfg, c, t, q))(
        params, cache, tok, pos)
    np.testing.assert_allclose(logits_dec, logits_full[:, S - 1, :],
                               rtol=1e-3, atol=1e-3)


def test_engine_matches_stepwise_oracle():
    cfg = _f32("llama3_2_3b")
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=128, slots=2, min_bucket=16))
    outs = eng.generate([[5, 6, 7, 8], [1, 2, 3]], max_new=8)
    toks = [5, 6, 7, 8]
    for _ in range(8):
        logits, _ = api.forward(params, cfg, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert outs[0] == toks[4:]
    assert len(outs[1]) == 8


def test_engine_continuous_batching():
    """More requests than slots: the engine queues and completes all."""
    cfg = _f32("qwen2_0_5b")
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=64, slots=2, min_bucket=8))
    outs = eng.generate([[1, 2], [3, 4], [5, 6], [7, 8], [9]], max_new=4)
    assert len(outs) == 5 and all(len(o) == 4 for o in outs)


def test_engine_ssm_chunk_alignment():
    cfg = _f32("mamba2_370m")
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=256, slots=1))
    chunk = cfg.ssm.chunk
    with pytest.raises(ValueError):
        eng.add_request([1] * (chunk + 1))
    outs = eng.generate([[2] * chunk], max_new=4)
    assert len(outs[0]) == 4

    # exactness: engine output == stepwise oracle
    toks = [2] * chunk
    for _ in range(4):
        logits, _ = api.forward(params, cfg, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert outs[0] == toks[chunk:]

"""Hypothesis property tests on the system's invariants (each test skips
with a reason when hypothesis is not installed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, st

from repro.core import (bcd, bdcd, block_forward_substitution, ca_bcd,
                        ca_bdcd, overlap_matrix, sample_blocks, solve_spd)

from _x64 import x64_mode  # noqa: F401

dims = st.integers(min_value=6, max_value=40)


def _problem(seed, d, n):
    k1, k2 = jax.random.split(jax.random.key(seed))
    X = jax.random.normal(k1, (d, n), jnp.float64)
    y = jax.random.normal(k2, (n,), jnp.float64)
    return X, y


# The two solver-equivalence properties compile two solvers per example and
# are by far the slowest cases here when hypothesis is installed; the PR gate
# runs `-m "not slow"`, the full tier-1 suite (`make test-all`) covers them.
@pytest.mark.slow
@given(seed=st.integers(0, 2**16), d=dims, n=dims,
       b=st.integers(1, 5), s=st.integers(1, 6),
       lam=st.floats(1e-6, 10.0))
def test_ca_bcd_equals_bcd(seed, d, n, b, s, lam):
    """THE paper property: identical iterates for every (d, n, b, s, lam)."""
    b = min(b, d)
    X, y = _problem(seed, d, n)
    iters = 2 * s
    idx = sample_blocks(jax.random.key(seed + 1), d, b, iters)
    r_cl = bcd(X, y, lam, b, iters, None, idx=idx)
    r_ca = ca_bcd(X, y, lam, b, s, iters, None, idx=idx)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-9, atol=1e-11)


@pytest.mark.slow
@given(seed=st.integers(0, 2**16), d=dims, n=dims,
       b=st.integers(1, 5), s=st.integers(1, 6),
       lam=st.floats(1e-4, 10.0))
def test_ca_bdcd_equals_bdcd(seed, d, n, b, s, lam):
    b = min(b, n)
    X, y = _problem(seed, d, n)
    iters = 2 * s
    idx = sample_blocks(jax.random.key(seed + 2), n, b, iters)
    r_cl = bdcd(X, y, lam, b, iters, None, idx=idx)
    r_ca = ca_bdcd(X, y, lam, b, s, iters, None, idx=idx)
    np.testing.assert_allclose(r_ca.w, r_cl.w, rtol=1e-9, atol=1e-11)


@given(seed=st.integers(0, 2**16), n_total=st.integers(4, 200),
       b=st.integers(1, 4), iters=st.integers(1, 10))
def test_sampling_without_replacement(seed, n_total, b, iters):
    b = min(b, n_total)
    idx = np.asarray(sample_blocks(jax.random.key(seed), n_total, b, iters))
    assert idx.shape == (iters, b)
    assert idx.min() >= 0 and idx.max() < n_total
    for row in idx:
        assert len(set(row.tolist())) == b  # no replacement within a block


@given(seed=st.integers(0, 2**16), s=st.integers(1, 5), b=st.integers(1, 4))
def test_block_forward_substitution_oracle(seed, s, b):
    """The CA inner loop solves the block lower-triangular system exactly."""
    sb = s * b
    k1, k2 = jax.random.split(jax.random.key(seed))
    M = jax.random.normal(k1, (sb, sb), jnp.float64)
    A = M @ M.T + sb * jnp.eye(sb, dtype=jnp.float64)  # SPD
    base = jax.random.normal(k2, (sb,), jnp.float64)
    x = block_forward_substitution(A, base, s, b)
    # oracle: dense solve of the block-lower-triangular part of A
    Ab = np.asarray(A).reshape(s, b, s, b)
    L = np.zeros((sb, sb))
    for i in range(s):
        for j in range(i + 1):
            L[i*b:(i+1)*b, j*b:(j+1)*b] = Ab[i, :, j, :]
    expected = np.linalg.solve(L, np.asarray(base))
    np.testing.assert_allclose(x, expected, rtol=1e-9, atol=1e-11)


@given(seed=st.integers(0, 2**16), m=st.integers(2, 30))
def test_overlap_matrix_properties(seed, m):
    idx = jax.random.randint(jax.random.key(seed), (m,), 0, 10)
    O = np.asarray(overlap_matrix(idx))
    assert np.allclose(O, O.T)
    assert np.all(np.diag(O) == 1.0)
    assert set(np.unique(O)).issubset({0.0, 1.0})


@given(seed=st.integers(0, 2**16), n=st.integers(2, 24))
def test_solve_spd(seed, n):
    k1, k2 = jax.random.split(jax.random.key(seed))
    M = jax.random.normal(k1, (n, n), jnp.float64)
    A = M @ M.T + n * jnp.eye(n, dtype=jnp.float64)
    rhs = jax.random.normal(k2, (n,), jnp.float64)
    x = solve_spd(A, rhs)
    np.testing.assert_allclose(A @ x, rhs, rtol=1e-9, atol=1e-9)

"""The PR-2..4 dual operand strategy -- ``X.T`` bound as a row-major
operand -- reconstructed OUTSIDE the engine as the invariance baseline the
layout tests compare against.  The shipped ``DualRidge`` binds the original
(d, n) layout; this subclass is the only place the pre-transpose still
exists on the test side (benchmarks/kernels_bench.py carries its own
measurement-only copy because the bench harness runs without tests/ on the
path)."""
import dataclasses

from repro.core.engine import DualRidge
from repro.kernels.gram import RowMajorOperand


class LegacyPreTransposeDual(DualRidge):
    """Measurement/baseline only: binds ``RowMajorOperand(X.T)``."""

    def bind(self, X, y, lam, *, x0=None, w_ref=None):
        bound = super().bind(X, y, lam, x0=x0, w_ref=w_ref)
        return dataclasses.replace(bound, operand=RowMajorOperand(X.T))

    def bind_shard(self, Xl, yl, lam, *, d, n):
        bound = super().bind_shard(Xl, yl, lam, d=d, n=n)
        return dataclasses.replace(bound, operand=RowMajorOperand(Xl.T))

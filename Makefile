.PHONY: test test-fast bench bench-smoke

# Tier-1 verify (ROADMAP.md): the full suite, fail-fast.
test:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q

# Skip the slow multi-device integration checks (marker registered in pytest.ini).
test-fast:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run

# Tiny-shape kernel benches in ref/interpret mode; writes the BENCH_smoke.json
# perf-trajectory baseline (wall us + modeled HBM bytes/iter of the panel-free
# packet vs the gather-then-pack baseline).
bench-smoke:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run --smoke

.PHONY: test test-all test-fast bench bench-smoke bench-serve-smoke check-contracts check-faults check-pipeline

# Tier-1 verify (ROADMAP.md): the full suite, fail-fast.
test:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q

# The full suite including every slow-marked case, not fail-fast -- the
# long-form complement of the CI PR gate (which runs `-m "not slow"`).
test-all:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -q

# Skip the slow cases (marker registered in pytest.ini): the CI PR gate.
test-fast:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run

# Tiny-shape kernel benches in ref/interpret mode; writes the BENCH_smoke.json
# perf-trajectory baseline (wall us + modeled HBM bytes/iter of the panel-free
# packet vs the gather-then-pack baseline).
bench-smoke:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run --smoke

# Just the multi-tenant solve-throughput rows (solves/s at T = 1/64/4096 and
# the 64v1 amortization ratio; DESIGN.md section 8).  --only never clobbers
# the committed BENCH_smoke.json baseline -- the canonical `bench-smoke` run
# (which includes serve_bench) is what refreshes it.
bench-serve-smoke:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run --smoke --only serve_bench

# Static contract sweep (DESIGN.md section 6): lower every registered solver
# and verify the declared communication/memory contracts, validate kernel
# plans, and lint source conventions.  Writes ANALYSIS.json; mirrors the CI
# `contracts` job.
check-contracts:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m repro.analysis sweep -o ANALYSIS.json

# Fault-injection + recovery suite (DESIGN.md section 7): the detection
# matrix, the clean-solve bitwise no-op, the checkpoint writer-error paths,
# and the f64 elastic-resume acceptance (8-device subprocess).  Mirrors the
# CI `faults` job.
check-faults:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q -m "not slow" tests/test_faults.py tests/test_checkpoint.py

# Pipelined wire schedule (DESIGN.md section 9): ring == psum equivalence for
# every registered formulation (single + batched, even + ragged), the
# declared collective-permute schedule machine-counted, the evil-extra-hop
# mutation caught, fault parity with the psum backend, and the accelerated
# formulation's beta=0 bit-for-bit gate.  Mirrors the CI `pipeline` job.
check-pipeline:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q \
		tests/test_distributed.py::test_pipelined_wire_schedule \
		tests/test_analysis.py::test_mutation_extra_hop_caught \
		tests/test_faults.py::test_pipelined_fault_parity \
		tests/test_accelerated.py

.PHONY: test test-fast bench

# Tier-1 verify (ROADMAP.md): the full suite, fail-fast.
test:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q

# Skip the slow multi-device integration checks (marker registered in pytest.ini).
test-fast:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m benchmarks.run
